//! Routing engines and forwarding-table machinery.
//!
//! [`Lft`] is the linear forwarding table a centralized fabric manager
//! uploads to every switch: `output port = lft[(switch, destination node)]`.
//! Every algorithm is a stateful [`RoutingEngine`]: an object owning its
//! persistent workspace (CSR prep, BFS queues, distance/load arrays, cost
//! buffers) whose [`RoutingEngine::route_into`] recomputes the full LFT
//! with **zero heap allocation** in steady state, and whose
//! [`RoutingEngine::validate`] reuses just-computed costs where the
//! pipeline has them (see DESIGN.md). Engines are constructed through
//! [`registry`] by [`Algo`] or by name; [`route`]/[`route_unchecked`]
//! remain one-shot convenience wrappers over fresh engine construction.
//! All engines are deterministic and oblivious (no traffic knowledge):
//!
//! * [`dmodc`] — **the paper's contribution**: closed-form modulo routing
//!   for degraded PGFTs (Algorithms 1–2, equations (1)–(4)).
//! * [`dmodk`] — the non-degraded PGFT baseline Dmodc generalizes.
//! * [`ftree`] — OpenSM's fat-tree engine (per-destination balancing).
//! * [`updn`] — OpenSM UPDN: up*/down* restricted shortest paths.
//! * [`minhop`] — OpenSM MinHop: unrestricted shortest paths.
//! * [`sssp`] — load-adaptive single-source shortest-path routing
//!   (OpenSM's (DF)SSSP without virtual-lane assignment, as in the paper).

pub mod common;
pub mod delta;
pub mod dmodc;
pub mod dmodk;
pub mod dump;
pub mod engine;
pub mod ftree;
pub mod minhop;
pub mod registry;
pub mod snapshot;
pub mod sssp;
pub mod updn;
pub mod validity;
pub mod workspace;

pub use delta::{DeltaConfig, DeltaOutcome, DeltaStats, FallbackReason};
pub use engine::{Capabilities, RoutingEngine};
pub use snapshot::Snapshot;
pub use workspace::{RerouteTimings, RerouteWorkspace};

use crate::topology::{NodeId, PortTarget, SwitchId, Topology};

/// Sentinel output port for "destination unreachable from this switch".
pub const NO_ROUTE: u16 = u16::MAX;

/// Linear forwarding tables for a whole fabric: row per switch, column per
/// destination node.
#[derive(Clone, Debug)]
pub struct Lft {
    ports: Vec<u16>,
    num_nodes: usize,
}

impl Lft {
    pub fn new(num_switches: usize, num_nodes: usize) -> Self {
        Self {
            ports: vec![NO_ROUTE; num_switches * num_nodes],
            num_nodes,
        }
    }

    /// Re-shape in place to `num_switches × num_nodes`, resetting every
    /// entry to [`NO_ROUTE`] — no allocation once capacity has converged
    /// (the workspace reroute path).
    pub fn reset(&mut self, num_switches: usize, num_nodes: usize) {
        self.num_nodes = num_nodes;
        self.ports.clear();
        self.ports.resize(num_switches * num_nodes, NO_ROUTE);
    }

    /// Become a byte-for-byte copy of `other`, reusing this table's
    /// buffer — no allocation once capacity has converged (the
    /// snapshot-restore hot path runs this once per campaign sample;
    /// the derived `Clone` would reallocate).
    pub fn copy_from(&mut self, other: &Lft) {
        self.num_nodes = other.num_nodes;
        self.ports.clear();
        self.ports.extend_from_slice(&other.ports);
    }

    #[inline]
    pub fn get(&self, sw: SwitchId, dst: NodeId) -> u16 {
        self.ports[sw as usize * self.num_nodes + dst as usize]
    }

    #[inline]
    pub fn set(&mut self, sw: SwitchId, dst: NodeId, port: u16) {
        self.ports[sw as usize * self.num_nodes + dst as usize] = port;
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_switches(&self) -> usize {
        if self.num_nodes == 0 {
            0
        } else {
            self.ports.len() / self.num_nodes
        }
    }

    /// Mutable row for one switch (used by parallel route computation).
    pub fn row_mut(&mut self, sw: SwitchId) -> &mut [u16] {
        let n = self.num_nodes;
        &mut self.ports[sw as usize * n..(sw as usize + 1) * n]
    }

    /// Raw table access (row-major switch × destination).
    pub fn raw(&self) -> &[u16] {
        &self.ports
    }

    /// Mutable raw access for the parallel row fill.
    pub(crate) fn raw_mut(&mut self) -> &mut [u16] {
        &mut self.ports
    }

    /// Split into per-switch rows for parallel writers.
    pub fn rows_mut(&mut self) -> Vec<&mut [u16]> {
        self.ports.chunks_mut(self.num_nodes.max(1)).collect()
    }

    /// Number of table entries that differ from `other` (same shape
    /// required) — the upload-delta metric used by the fabric manager.
    pub fn delta(&self, other: &Lft) -> usize {
        assert_eq!(self.ports.len(), other.ports.len());
        self.ports
            .iter()
            .zip(&other.ports)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Switch rows whose content differs from `prev` — the caller-side
    /// dirty set for incremental consumers
    /// ([`PathTensor::update`](crate::analysis::paths::PathTensor::update)).
    /// When the shapes differ every row is returned (those consumers
    /// rebuild from scratch there anyway).
    pub fn changed_rows(&self, prev: &Lft) -> Vec<u32> {
        let mut out = Vec::new();
        self.changed_rows_into(prev, &mut out);
        out
    }

    /// [`Lft::changed_rows`] into a caller-reused buffer (the campaign
    /// sample loop derives tensor dirty sets per sample and must not
    /// allocate in steady state).
    pub fn changed_rows_into(&self, prev: &Lft, out: &mut Vec<u32>) {
        out.clear();
        if prev.num_switches() != self.num_switches() || prev.num_nodes != self.num_nodes {
            out.extend(0..self.num_switches() as u32);
            return;
        }
        let n = self.num_nodes.max(1);
        out.extend(
            (0..self.num_switches())
                .filter(|&s| prev.ports[s * n..(s + 1) * n] != self.ports[s * n..(s + 1) * n])
                .map(|s| s as u32),
        );
    }
}

impl Default for Lft {
    fn default() -> Self {
        Lft::new(0, 0)
    }
}

/// Routing engine selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Dmodc,
    Dmodk,
    Ftree,
    Updn,
    MinHop,
    Sssp,
}

impl Algo {
    pub const ALL: [Algo; 6] = [
        Algo::Dmodc,
        Algo::Dmodk,
        Algo::Ftree,
        Algo::Updn,
        Algo::MinHop,
        Algo::Sssp,
    ];

    /// The algorithms compared in the paper's Figure 2/3.
    pub const PAPER: [Algo; 5] = [
        Algo::Dmodc,
        Algo::Ftree,
        Algo::Updn,
        Algo::MinHop,
        Algo::Sssp,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Dmodc => "dmodc",
            Algo::Dmodk => "dmodk",
            Algo::Ftree => "ftree",
            Algo::Updn => "updn",
            Algo::MinHop => "minhop",
            Algo::Sssp => "sssp",
        }
    }

    /// Delegating wrapper over the [`std::str::FromStr`] impl.
    pub fn parse(s: &str) -> Result<Algo, String> {
        s.parse()
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Algo {
    type Err = String;

    fn from_str(s: &str) -> Result<Algo, String> {
        Algo::ALL
            .iter()
            .copied()
            .find(|a| a.name() == s)
            .ok_or_else(|| {
                let known: Vec<&str> = Algo::ALL.iter().map(|a| a.name()).collect();
                format!("unknown algorithm {s:?} (expected one of: {})", known.join(", "))
            })
    }
}

/// Route `topo` with a freshly constructed engine. Returns an error if any
/// node pair is unroutable (the paper's validity condition — checked via
/// [`RoutingEngine::validate`], so cost-reusing engines skip the rebuild);
/// the partially-filled table is still available through
/// [`route_unchecked`].
pub fn route(algo: Algo, topo: &Topology) -> Result<Lft, String> {
    let mut engine = registry::create(algo);
    let mut lft = Lft::default();
    engine.route_into(topo, &mut lft);
    engine.validate(topo, &lft)?;
    Ok(lft)
}

/// Route without the validity pass (callers that expect degraded-to-invalid
/// topologies and want the table anyway). One-shot compatibility wrapper
/// over [`registry::create`]; hold a [`RoutingEngine`] instead when
/// rerouting repeatedly, so the workspace is reused.
pub fn route_unchecked(algo: Algo, topo: &Topology) -> Lft {
    registry::create(algo).route_once(topo)
}

/// Trace the route of `(src, dst)` through `lft`, returning the sequence of
/// global directed-port ids traversed (switch egress ports, including the
/// final leaf→node port). `None` when the route is incomplete or loops.
pub fn trace(topo: &Topology, lft: &Lft, src: NodeId, dst: NodeId) -> Option<Vec<u32>> {
    let mut ports = Vec::with_capacity(2 * topo.num_levels as usize + 1);
    let mut sw = topo.nodes[src as usize].leaf;
    let max_hops = 4 * topo.num_levels as usize + 4;
    loop {
        let port = lft.get(sw, dst);
        if port == NO_ROUTE {
            return None;
        }
        ports.push(topo.port_id(sw, port));
        match topo.switches[sw as usize].ports[port as usize] {
            PortTarget::Node { node } if node == dst => return Some(ports),
            PortTarget::Node { .. } => return None, // routed into the wrong node
            PortTarget::Switch { sw: next, .. } => sw = next,
        }
        if ports.len() > max_hops {
            return None; // loop
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lft_get_set_delta() {
        let mut a = Lft::new(3, 4);
        assert_eq!(a.get(1, 2), NO_ROUTE);
        a.set(1, 2, 7);
        assert_eq!(a.get(1, 2), 7);
        let mut b = a.clone();
        assert_eq!(a.delta(&b), 0);
        b.set(0, 0, 3);
        b.set(2, 3, 4);
        assert_eq!(a.delta(&b), 2);
    }

    #[test]
    fn lft_changed_rows_names_exactly_the_differing_rows() {
        let a = Lft::new(3, 4);
        let mut b = a.clone();
        assert!(b.changed_rows(&a).is_empty());
        b.set(0, 1, 5);
        b.set(2, 0, 9);
        assert_eq!(b.changed_rows(&a), vec![0, 2]);
        // Shape mismatch: every row is dirty (consumers rebuild anyway).
        let c = Lft::new(2, 4);
        assert_eq!(b.changed_rows(&c), vec![0, 1, 2]);
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::parse(a.name()).unwrap(), a);
            // Display/FromStr roundtrip (parse/name delegate to them).
            assert_eq!(a.to_string().parse::<Algo>().unwrap(), a);
            assert_eq!(a.to_string(), a.name());
        }
        assert!(Algo::parse("nope").is_err());
        assert!("Dmodc".parse::<Algo>().is_err(), "names are lowercase");
    }

    #[test]
    fn rows_mut_partitions() {
        let mut a = Lft::new(4, 5);
        {
            let rows = a.rows_mut();
            assert_eq!(rows.len(), 4);
            for (i, r) in rows.into_iter().enumerate() {
                r[0] = i as u16;
            }
        }
        for sw in 0..4 {
            assert_eq!(a.get(sw, 0), sw as u16);
        }
    }
}
