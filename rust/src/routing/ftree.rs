//! OpenSM-style **Ftree** routing (Zahavi's optimized fat-tree engine).
//!
//! Destinations are routed one by one (leaves in UUID order, nodes in port
//! order — the internal ordering the paper's shift analysis aligns with).
//! For each destination: a *wave* climbs from the destination's leaf,
//! assigning the down-going route on every switch that can reach an
//! already-routed switch below, choosing the least-subscribed down port
//! (per-port counters persist across destinations, which is what spreads
//! consecutive destinations across parallel spines). Switches not reached
//! by the wave (non-ancestors under degradation) then route *up* toward a
//! routed up-neighbor, balanced by separate up-port counters.

use super::common::{Prep, PrepScratch};
use super::engine::{Capabilities, RoutingEngine};
use super::{Lft, NO_ROUTE};
use crate::topology::{SwitchId, Topology};

/// Persistent buffers for repeated Ftree reroutes: CSR prep, the two
/// port-load counter arrays, UUID-ordered leaf/level index lists, and the
/// per-destination wave marker.
#[derive(Default)]
pub struct Workspace {
    prep: Prep,
    prep_scratch: PrepScratch,
    down_load: Vec<u32>,
    up_load: Vec<u32>,
    /// Leaf switches, UUID-sorted (destination order).
    leaves: Vec<SwitchId>,
    /// Per level: switches UUID-sorted. Only the first `num_levels`
    /// entries are live for the current topology; the list never shrinks
    /// so inner buffers survive shape changes.
    levels: Vec<Vec<SwitchId>>,
    routed: Vec<bool>,
}

/// Ftree into reused buffers (allocation-free in steady state).
pub fn route_into(topo: &Topology, ws: &mut Workspace, out: &mut Lft) {
    Prep::build_into(topo, &mut ws.prep, &mut ws.prep_scratch);
    let Workspace {
        prep,
        down_load,
        up_load,
        leaves,
        levels,
        routed,
        ..
    } = ws;
    let ns = topo.switches.len();
    out.reset(ns, topo.nodes.len());
    down_load.clear();
    down_load.resize(topo.num_ports(), 0);
    up_load.clear();
    up_load.resize(topo.num_ports(), 0);

    // Destination order: leaves by UUID, nodes in port-rank order. UUIDs
    // are unique, so the unstable sorts below are deterministic.
    leaves.clear();
    leaves.extend_from_slice(&prep.leaves);
    leaves.sort_unstable_by_key(|&l| topo.switches[l as usize].uuid);

    // Switches per level (descending for the up-routing pass), stable
    // UUID order inside each level (OpenSM iterates by GUID).
    let max_level = topo.num_levels as usize;
    if levels.len() < max_level {
        levels.resize_with(max_level, Vec::new);
    }
    for lvl in levels.iter_mut() {
        lvl.clear();
    }
    for s in 0..ns as SwitchId {
        levels[topo.switches[s as usize].level as usize].push(s);
    }
    for lvl in levels[..max_level].iter_mut() {
        lvl.sort_unstable_by_key(|&s| topo.switches[s as usize].uuid);
    }

    routed.clear();
    routed.resize(ns, false);
    for &leaf in leaves.iter() {
        let li = prep.leaf_index[leaf as usize];
        for &d in prep.nodes_of_leaf_idx(li) {
            routed.fill(false);
            routed[leaf as usize] = true;
            out.set(leaf, d, topo.nodes[d as usize].leaf_port);

            // Wave upward: level k switches route down toward any routed
            // lower switch.
            for k in 1..max_level {
                for &s in &levels[k] {
                    let su = s as usize;
                    let mut best: Option<(u32, usize, u16)> = None;
                    for (gi, g) in prep.groups(su).enumerate() {
                        if g.up || !routed[g.remote as usize] {
                            continue;
                        }
                        for &p in g.ports {
                            let pid = topo.port_id(s, p) as usize;
                            let key = (down_load[pid], gi, p);
                            if best.is_none_or(|b| key < b) {
                                best = Some(key);
                            }
                        }
                    }
                    if let Some((_, _, port)) = best {
                        out.set(s, d, port);
                        down_load[topo.port_id(s, port) as usize] += 1;
                        routed[su] = true;
                    }
                }
            }
            // Up-routing pass for non-ancestors, upper levels first so a
            // lower switch can chain through an already-up-routed one.
            for k in (0..max_level - 1).rev() {
                for &s in &levels[k] {
                    let su = s as usize;
                    if routed[su] {
                        continue;
                    }
                    let mut best: Option<(u32, usize, u16)> = None;
                    for (gi, g) in prep.groups(su).enumerate() {
                        if !g.up || !routed[g.remote as usize] {
                            continue;
                        }
                        for &p in g.ports {
                            let pid = topo.port_id(s, p) as usize;
                            let key = (up_load[pid], gi, p);
                            if best.is_none_or(|b| key < b) {
                                best = Some(key);
                            }
                        }
                    }
                    if let Some((_, _, port)) = best {
                        out.set(s, d, port);
                        up_load[topo.port_id(s, port) as usize] += 1;
                        routed[su] = true;
                    }
                }
            }
        }
    }
    // Unrouted entries remain NO_ROUTE by construction of `Lft::reset`.
    let _ = NO_ROUTE;
}

/// One-shot wrapper over [`route_into`] with a fresh [`Workspace`].
pub fn route(topo: &Topology) -> Lft {
    let mut ws = Workspace::default();
    let mut out = Lft::default();
    route_into(topo, &mut ws, &mut out);
    out
}

/// The stateful Ftree [`RoutingEngine`]. Port-load counters are reset per
/// reroute, so the engine stays deterministic and history-free.
#[derive(Default)]
pub struct Engine {
    ws: Workspace,
}

impl RoutingEngine for Engine {
    fn name(&self) -> &'static str {
        "ftree"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic_history_free: true,
            ..Capabilities::default()
        }
    }

    fn route_into(&mut self, topo: &Topology, out: &mut Lft) {
        route_into(topo, &mut self.ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::validity;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn intact_pgft_valid_and_updown() {
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        validity::check(&t, &lft).unwrap();
        let st = validity::stats(&t, &lft);
        assert_eq!(st.downup_turns, 0, "ftree is up*/down* by construction");
        assert!(validity::channel_dependency_acyclic(&t, &lft));
    }

    #[test]
    fn down_ports_spread_consecutive_destinations() {
        // On an intact PGFT the per-port counters must spread the nodes of
        // one remote leaf across distinct spine down-ports (the property
        // that makes Ftree shift-optimal).
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        // Pick a top switch and check its down-port usage is balanced.
        let top = (0..t.switches.len() as u32)
            .find(|&s| t.switches[s as usize].level == 2)
            .unwrap();
        let mut counts = std::collections::HashMap::new();
        for d in 0..t.nodes.len() as u32 {
            let p = lft.get(top, d);
            if p != NO_ROUTE {
                *counts.entry(p).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        let min = counts.values().min().copied().unwrap_or(0);
        assert!(max - min <= 2, "top-switch down-port imbalance: {counts:?}");
    }

    #[test]
    fn degraded_keeps_updown() {
        use crate::topology::degrade;
        use crate::util::rng::Rng;
        let t = PgftParams::small().build();
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let dt = degrade::remove_random_switches(&t, &mut rng, 3);
            let lft = route(&dt);
            assert_eq!(validity::stats(&dt, &lft).downup_turns, 0);
        }
    }

    // Engine-vs-free-function bit-identity across workspace reuse is
    // covered for all engines by tests/equivalence.rs
    // (engines_bit_identical_to_free_functions_across_reuse).
}
