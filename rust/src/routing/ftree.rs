//! OpenSM-style **Ftree** routing (Zahavi's optimized fat-tree engine).
//!
//! Destinations are routed one by one (leaves in UUID order, nodes in port
//! order — the internal ordering the paper's shift analysis aligns with).
//! For each destination: a *wave* climbs from the destination's leaf,
//! assigning the down-going route on every switch that can reach an
//! already-routed switch below, choosing the least-subscribed down port
//! (per-port counters persist across destinations, which is what spreads
//! consecutive destinations across parallel spines). Switches not reached
//! by the wave (non-ancestors under degradation) then route *up* toward a
//! routed up-neighbor, balanced by separate up-port counters.

use super::common::Prep;
use super::{Lft, NO_ROUTE};
use crate::topology::{SwitchId, Topology};

pub fn route(topo: &Topology) -> Lft {
    let prep = Prep::new(topo);
    let ns = topo.switches.len();
    let mut lft = Lft::new(ns, topo.nodes.len());
    let mut down_load = vec![0u32; topo.num_ports()];
    let mut up_load = vec![0u32; topo.num_ports()];

    // Destination order: leaves by UUID, nodes in port-rank order.
    let mut leaves = prep.leaves.clone();
    leaves.sort_by_key(|&l| topo.switches[l as usize].uuid);

    // Switches per level (descending for the up-routing pass).
    let max_level = topo.num_levels;
    let mut by_level: Vec<Vec<SwitchId>> = vec![Vec::new(); max_level as usize];
    for s in 0..ns as SwitchId {
        by_level[topo.switches[s as usize].level as usize].push(s);
    }
    // Stable UUID order inside each level (OpenSM iterates by GUID).
    for lvl in &mut by_level {
        lvl.sort_by_key(|&s| topo.switches[s as usize].uuid);
    }

    let mut routed = vec![false; ns];
    for &leaf in &leaves {
        for d in topo.nodes_of_leaf(leaf) {
            routed.fill(false);
            routed[leaf as usize] = true;
            lft.set(leaf, d, topo.nodes[d as usize].leaf_port);

            // Wave upward: level k switches route down toward any routed
            // lower switch.
            for k in 1..max_level as usize {
                for &s in &by_level[k] {
                    let su = s as usize;
                    let mut best: Option<(u32, usize, u16)> = None;
                    for (gi, g) in prep.groups(su).enumerate() {
                        if g.up || !routed[g.remote as usize] {
                            continue;
                        }
                        for &p in g.ports {
                            let pid = topo.port_id(s, p) as usize;
                            let key = (down_load[pid], gi, p);
                            if best.map_or(true, |b| key < b) {
                                best = Some(key);
                            }
                        }
                    }
                    if let Some((_, _, port)) = best {
                        lft.set(s, d, port);
                        down_load[topo.port_id(s, port) as usize] += 1;
                        routed[su] = true;
                    }
                }
            }
            // Up-routing pass for non-ancestors, upper levels first so a
            // lower switch can chain through an already-up-routed one.
            for k in (0..max_level as usize - 1).rev() {
                for &s in &by_level[k] {
                    let su = s as usize;
                    if routed[su] {
                        continue;
                    }
                    let mut best: Option<(u32, usize, u16)> = None;
                    for (gi, g) in prep.groups(su).enumerate() {
                        if !g.up || !routed[g.remote as usize] {
                            continue;
                        }
                        for &p in g.ports {
                            let pid = topo.port_id(s, p) as usize;
                            let key = (up_load[pid], gi, p);
                            if best.map_or(true, |b| key < b) {
                                best = Some(key);
                            }
                        }
                    }
                    if let Some((_, _, port)) = best {
                        lft.set(s, d, port);
                        up_load[topo.port_id(s, port) as usize] += 1;
                        routed[su] = true;
                    }
                }
            }
        }
    }
    let _ = NO_ROUTE; // unrouted entries remain NO_ROUTE by construction
    lft
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::validity;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn intact_pgft_valid_and_updown() {
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        validity::check(&t, &lft).unwrap();
        let st = validity::stats(&t, &lft);
        assert_eq!(st.downup_turns, 0, "ftree is up*/down* by construction");
        assert!(validity::channel_dependency_acyclic(&t, &lft));
    }

    #[test]
    fn down_ports_spread_consecutive_destinations() {
        // On an intact PGFT the per-port counters must spread the nodes of
        // one remote leaf across distinct spine down-ports (the property
        // that makes Ftree shift-optimal).
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        // Pick a top switch and check its down-port usage is balanced.
        let top = (0..t.switches.len() as u32)
            .find(|&s| t.switches[s as usize].level == 2)
            .unwrap();
        let mut counts = std::collections::HashMap::new();
        for d in 0..t.nodes.len() as u32 {
            let p = lft.get(top, d);
            if p != NO_ROUTE {
                *counts.entry(p).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        let min = counts.values().min().copied().unwrap_or(0);
        assert!(max - min <= 2, "top-switch down-port imbalance: {counts:?}");
    }

    #[test]
    fn degraded_keeps_updown() {
        use crate::topology::degrade;
        use crate::util::rng::Rng;
        let t = PgftParams::small().build();
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let dt = degrade::remove_random_switches(&t, &mut rng, 3);
            let lft = route(&dt);
            assert_eq!(validity::stats(&dt, &lft).downup_turns, 0);
        }
    }
}
