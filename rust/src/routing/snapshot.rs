//! Baseline snapshots: freeze a completed reroute's pipeline products so
//! independent samples can *fork* from it instead of recomputing from
//! scratch.
//!
//! The degradation-sweep campaign (paper §4, Figs. 4–5) draws hundreds of
//! independent throws per degradation level, and in the paper's headline
//! regime — "up to 1 % of random degradation" — every throw differs from
//! the intact fabric by a handful of cables. The sequential delta path
//! (`routing::delta`) cannot exploit that: it diffs against the *previous
//! reroute*, and campaign samples are not sequenced events but independent
//! forks of one shared baseline. A [`Snapshot`] closes that gap:
//!
//! * [`RerouteWorkspace::snapshot`](super::RerouteWorkspace::snapshot)
//!   captures the products of the workspace's most recent reroute — the
//!   CSR `Prep` structure, Algorithm-1 costs/dividers, Algorithm-2 NIDs
//!   (as a pre-captured [`PrevProducts`] diff baseline) — together with
//!   the LFT those products produced, behind one immutable `Arc`. Cloning
//!   a `Snapshot` is a reference-count bump: campaign workers share one
//!   baseline per engine instead of each holding a copy.
//! * [`RerouteWorkspace::restore_from`](super::RerouteWorkspace::restore_from)
//!   re-arms a workspace so its **next** `reroute_delta_into` diffs
//!   against the snapshot instead of the previous sample. The restore is
//!   copy-on-write in spirit: the shared buffers are copied into the
//!   worker's reused scratch (`Vec::clone_from`, allocation-free once
//!   capacities converge) only at the moment the worker needs a private
//!   mutable view; the `Arc` itself is never mutated.
//! * [`Snapshot::restore_lft_into`] rewinds a caller's table buffer to the
//!   baseline tables, which is the delta fill's required starting state.
//!
//! The contract is the same bit-identity promise the delta path makes
//! (`tests/campaign_fork.rs` fuzzes it): a forked sample — restore, then
//! delta-reroute the degraded topology — produces tables byte-for-byte
//! equal to an independent from-scratch reroute, for every sample, with
//! the usual fallbacks (shape change, isolated leaf, NID change,
//! threshold) degrading to a full row fill over the already-rebuilt
//! products. [`PathTensor`](crate::analysis::paths::PathTensor) offers the
//! matching analysis-side snapshot so the risk tensor forks too.

use super::delta::PrevProducts;
use super::Lft;
use std::sync::Arc;

/// An immutable, cheaply clonable baseline: the pipeline products and
/// tables of one completed reroute (see the module docs). Created by
/// [`RerouteWorkspace::snapshot`](super::RerouteWorkspace::snapshot) or
/// through [`RoutingEngine::fork_snapshot`](super::RoutingEngine::fork_snapshot);
/// consumed by `restore_from`/[`Snapshot::restore_lft_into`].
pub struct Snapshot {
    data: Arc<SnapshotData>,
}

struct SnapshotData {
    /// The captured diff baseline (Prep structure, costs, dividers, NIDs).
    products: PrevProducts,
    /// The tables those products produced.
    lft: Lft,
}

impl Snapshot {
    /// Freeze `(products, lft)` as a shared baseline. `products` must be a
    /// live capture of the pipeline state that produced `lft` — the
    /// workspace entry point guarantees this.
    pub(crate) fn from_parts(products: PrevProducts, lft: Lft) -> Self {
        debug_assert!(products.is_valid(), "snapshot of an invalid capture");
        Self {
            data: Arc::new(SnapshotData { products, lft }),
        }
    }

    /// The captured diff baseline.
    pub(crate) fn products(&self) -> &PrevProducts {
        &self.data.products
    }

    /// Switch rows of the baseline tables.
    pub fn num_switches(&self) -> usize {
        self.data.lft.num_switches()
    }

    /// Destination columns of the baseline tables.
    pub fn num_nodes(&self) -> usize {
        self.data.lft.num_nodes()
    }

    /// The baseline tables (read-only; shared across clones).
    pub fn lft(&self) -> &Lft {
        &self.data.lft
    }

    /// Rewind `out` to the baseline tables, reusing its buffer (no
    /// allocation once capacity has converged). This is the required
    /// starting state for a forked `reroute_delta_into`: the delta fill
    /// patches dirty rows *on top of* the baseline.
    pub fn restore_lft_into(&self, out: &mut Lft) {
        out.copy_from(&self.data.lft);
    }
}

impl Clone for Snapshot {
    fn clone(&self) -> Self {
        Self {
            data: Arc::clone(&self.data),
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::routing::dmodc::{route_reference, Options};
    use crate::routing::{Lft, RerouteWorkspace};
    use crate::topology::pgft::PgftParams;
    use crate::topology::{degrade, Topology};
    use std::collections::HashSet;

    #[test]
    fn snapshot_is_shared_and_restores_the_exact_tables() {
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);
        let clone = snap.clone();
        assert_eq!(snap.num_switches(), t.switches.len());
        assert_eq!(snap.num_nodes(), t.nodes.len());
        let mut out = Lft::default();
        clone.restore_lft_into(&mut out);
        assert_eq!(out.raw(), lft.raw());
        assert_eq!(snap.lft().raw(), lft.raw());
    }

    #[test]
    fn restore_into_a_foreign_workspace_forks_correctly() {
        // A snapshot is self-contained: a workspace that never routed the
        // baseline can restore it and delta straight to a degraded sample.
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);

        let mut other = RerouteWorkspace::default();
        let mut out = Lft::default();
        let mut touched = Vec::new();
        let dead: HashSet<(u32, u16)> = [degrade::cables(&t)[0]].into_iter().collect();
        let d = degrade::apply(&t, &HashSet::new(), &dead);
        other.restore_from(&snap, &mut out);
        let outcome = other.reroute_delta_into(&d, &mut out, &mut touched);
        assert!(outcome.is_delta(), "{outcome:?}");
        let want = route_reference(&d, &Options::default());
        assert_eq!(out.raw(), want.raw());
        assert!(other.validate(&d, &out).is_ok());
    }

    #[test]
    fn snapshot_survives_the_workspace_moving_on() {
        // The Arc pins the baseline even while the source workspace keeps
        // rerouting other topologies — campaign workers rely on this.
        let t = PgftParams::small().build();
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);
        let baseline = lft.raw().to_vec();

        let mut topo = Topology::default();
        let dead_sw: HashSet<u32> =
            [degrade::removable_switches(&t)[0]].into_iter().collect();
        ws.materialize(&t, &dead_sw, &HashSet::new(), &mut topo);
        ws.reroute_into(&topo, &mut lft);
        assert_ne!(lft.raw(), &baseline[..], "the workspace really moved on");
        assert_eq!(snap.lft().raw(), &baseline[..], "the snapshot did not");
    }
}
