//! OpenSM-style **UPDN** routing: destination-based shortest paths
//! restricted to up*/down* shapes, balanced by global port-load counters.
//!
//! Per destination, switches are settled in BFS order from the
//! destination's leaf over a two-phase state space: a switch may always
//! step **up** into a settled switch, but may only step **down** into a
//! switch whose own chosen route is pure-down (this keeps the *realized*
//! destination-based paths up*/down*-shaped, which a naive per-phase BFS
//! does not guarantee under degradation). Ties are broken by lowest port
//! load, then remote UUID, then port index — mirroring OpenSM's
//! counter-based balancing with GUID tie-breaks.

use super::common::{Prep, PrepScratch};
use super::engine::{Capabilities, RoutingEngine};
use super::{Lft, NO_ROUTE};
use crate::topology::{SwitchId, Topology};
use std::collections::VecDeque;

/// Persistent buffers for repeated UPDN reroutes: CSR prep, the global
/// port-load counters, and the per-destination BFS state.
#[derive(Default)]
pub struct Workspace {
    prep: Prep,
    prep_scratch: PrepScratch,
    load: Vec<u32>,
    dist: Vec<u32>,
    pure: Vec<bool>,
    routed_port: Vec<u16>,
    queue: VecDeque<SwitchId>,
}

/// UPDN into reused buffers (allocation-free in steady state).
pub fn route_into(topo: &Topology, ws: &mut Workspace, out: &mut Lft) {
    Prep::build_into(topo, &mut ws.prep, &mut ws.prep_scratch);
    let Workspace {
        prep,
        load,
        dist,
        pure,
        routed_port,
        queue,
        ..
    } = ws;
    let ns = topo.switches.len();
    out.reset(ns, topo.nodes.len());
    load.clear();
    load.resize(topo.num_ports(), 0);
    dist.clear();
    dist.resize(ns, u32::MAX);
    pure.clear();
    pure.resize(ns, false);
    routed_port.clear();
    routed_port.resize(ns, NO_ROUTE);

    for d in 0..topo.nodes.len() as u32 {
        let node = topo.nodes[d as usize];
        let leaf = node.leaf;
        dist.fill(u32::MAX);
        pure.fill(false);
        routed_port.fill(NO_ROUTE);

        dist[leaf as usize] = 0;
        pure[leaf as usize] = true;
        routed_port[leaf as usize] = node.leaf_port;
        queue.clear();
        queue.push_back(leaf);

        while let Some(s) = queue.pop_front() {
            let su = s as usize;
            if s != leaf {
                // Choose the egress port among usable settled neighbors at
                // distance dist[s]-1.
                let mut best: Option<(bool, u32, usize, u16)> = None; // (is_up, load, group, port)
                for (gi, g) in prep.groups(su).enumerate() {
                    let r = g.remote as usize;
                    if dist[r] != dist[su] - 1 {
                        continue;
                    }
                    // Stepping down requires the target to continue purely
                    // downward; stepping up is always legal.
                    if !g.up && !pure[r] {
                        continue;
                    }
                    for &p in g.ports {
                        let pid = topo.port_id(s, p) as usize;
                        let key = (g.up, load[pid], gi, p);
                        if best.is_none_or(|b| key < b) {
                            best = Some(key);
                        }
                    }
                }
                let (is_up, _, _, port) = best.expect("settled switch must have a candidate");
                routed_port[su] = port;
                pure[su] = !is_up;
                load[topo.port_id(s, port) as usize] += 1;
            }
            // Relax neighbors: r can use s if r→s is an up step (always) or
            // a down step into a pure-down switch.
            for g in prep.groups(su) {
                let r = g.remote;
                if dist[r as usize] != u32::MAX {
                    continue;
                }
                let r_to_s_up = topo.switches[su].level > topo.switches[r as usize].level;
                if r_to_s_up || pure[su] {
                    dist[r as usize] = dist[su] + 1;
                    queue.push_back(r);
                }
            }
        }
        for s in 0..ns as u32 {
            if routed_port[s as usize] != NO_ROUTE {
                out.set(s, d, routed_port[s as usize]);
            }
        }
    }
}

/// One-shot wrapper over [`route_into`] with a fresh [`Workspace`].
pub fn route(topo: &Topology) -> Lft {
    let mut ws = Workspace::default();
    let mut out = Lft::default();
    route_into(topo, &mut ws, &mut out);
    out
}

/// The stateful UPDN [`RoutingEngine`]. Load counters are reset per
/// reroute, so the engine stays deterministic and history-free.
#[derive(Default)]
pub struct Engine {
    ws: Workspace,
}

impl RoutingEngine for Engine {
    fn name(&self) -> &'static str {
        "updn"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic_history_free: true,
            ..Capabilities::default()
        }
    }

    fn route_into(&mut self, topo: &Topology, out: &mut Lft) {
        route_into(topo, &mut self.ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::validity;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn intact_pgft_valid() {
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        validity::check(&t, &lft).unwrap();
        let st = validity::stats(&t, &lft);
        assert_eq!(st.downup_turns, 0, "UPDN must be up*/down*");
        assert!(validity::channel_dependency_acyclic(&t, &lft));
    }

    #[test]
    fn stays_updown_under_degradation() {
        use crate::topology::degrade;
        use crate::util::rng::Rng;
        let t = PgftParams::small().build();
        let mut rng = Rng::new(33);
        for _ in 0..15 {
            let dt = degrade::remove_random_links(&t, &mut rng, 6);
            let lft = route(&dt);
            let st = validity::stats(&dt, &lft);
            assert_eq!(st.downup_turns, 0, "UPDN must never turn down→up");
        }
    }

    #[test]
    fn balances_across_uplinks() {
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        let leaf = t.leaf_switches()[0];
        let mut counts = std::collections::HashMap::new();
        for d in 0..t.nodes.len() as u32 {
            if t.nodes[d as usize].leaf != leaf {
                *counts.entry(lft.get(leaf, d)).or_insert(0usize) += 1;
            }
        }
        // 10 remote destinations over 4 uplink ports.
        assert!(counts.len() >= 4, "should use all uplinks, got {counts:?}");
        assert!(counts.values().all(|&c| c <= 4), "imbalance: {counts:?}");
    }

    // Engine-vs-free-function bit-identity across workspace reuse is
    // covered for all engines by tests/equivalence.rs
    // (engines_bit_identical_to_free_functions_across_reuse).
}
