//! Incremental ("delta") rerouting support: bound which LFT rows a
//! fault/recovery event can change, so the reroute path skips the ~99%
//! of rows a single cable fault cannot touch.
//!
//! The full paper (arXiv:2211.13101) observes that most degradation
//! throws damage only a small fraction of subtrees; reacting below
//! full-recompute cost is where centralized fabric managers win
//! (cf. the HyperX fault-tolerant routing line, arXiv:2404.04315).
//! The danger of partial rerouting is silent drift from the routing
//! function — exactly what the paper criticizes in history-dependent
//! schemes. This module therefore makes one promise the test suite
//! enforces everywhere (`tests/delta_diff.rs`): **the delta path is
//! bit-identical to a from-scratch full reroute after every event.**
//!
//! The design keeps that promise *by construction* instead of by
//! event-type case analysis:
//!
//! 1. The cheap pipeline stages (CSR [`Prep`], Algorithm-1 [`Costs`],
//!    Algorithm-2 NIDs) are recomputed in full for the new topology —
//!    they are a small fraction of reaction latency, and recomputing
//!    them means every product the route fill consumes is exact.
//! 2. The products are *diffed* against the previous reroute's
//!    ([`PrevProducts`]). An LFT row `s` is a pure function of: the
//!    port groups of `s`, `divider[s]`, the cost rows of `s` and of its
//!    group remotes, the NIDs, and the per-leaf node lists. If none of
//!    those inputs changed, the old row **is** the new row — no
//!    recomputation, no approximation.
//! 3. Only rows (or single (switch, destination-leaf) blocks) whose
//!    inputs changed are refilled, through the same strength-reduced
//!    fill the full path uses (`dmodc::fill_rows_partial`).
//!
//! Whenever the dirty set cannot be bounded cheaply — first call,
//! shape change (switch/node sets differ), a leaf without uplinks on
//! either side of the event, a NID permutation change (Algorithm-2
//! clustering crossed a subtree boundary), or damage above the
//! configured threshold — the path falls back to a full row fill and
//! reports [`FallbackReason`]. The fallback *is* the full reroute: the
//! products were already rebuilt, so nothing is wasted.
//!
//! **Batch coalescing** (the fabric service loop,
//! `fabric::service`): the diff in step 2 is state-vs-state — previous
//! products against current products — not event-vs-event. Nothing
//! here inspects which events happened between the two reroutes, so a
//! burst of N cable events coalesced into *one* reroute yields exactly
//! the dirty set of the net state change, and the result is
//! byte-identical to applying the N events one at a time and keeping
//! the final tables (events whose effects cancel — a down/up flap
//! inside one window — dirty nothing at all). That composition
//! property is what makes the service's single-reaction-per-burst
//! guarantee a corollary of the per-event one; `tests/service_coalesce.rs`
//! fuzzes it end to end.

use super::common::{Costs, Prep};
use crate::topology::SwitchId;

/// Knobs for the delta reroute path (owned by
/// [`RerouteWorkspace`](super::RerouteWorkspace)).
#[derive(Clone, Copy, Debug)]
pub struct DeltaConfig {
    /// Fall back to a full row fill when more than this fraction of
    /// switch rows is dirty (the partial fill's bookkeeping would cost
    /// more than it saves, and upload accounting degenerates to a full
    /// diff anyway).
    pub max_dirty_row_frac: f64,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        Self {
            max_dirty_row_frac: 0.5,
        }
    }
}

/// Why the delta path fell back to a full row fill.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FallbackReason {
    /// The engine does not implement incremental rerouting
    /// (`Capabilities::incremental` is false).
    Unsupported,
    /// No previous reroute to diff against (cold workspace, or the
    /// caller's output buffer does not match the last products).
    NoHistory,
    /// Switch, leaf, or node sets differ from the previous topology —
    /// row indices are not comparable.
    ShapeChanged,
    /// A leaf switch has no uplink group on one side of the event
    /// (disconnected destinations; subtree structure unbounded).
    IsolatedLeaf,
    /// Algorithm-2 node identifiers changed — the clustering crossed a
    /// subtree boundary, so every row's modulo arithmetic shifted.
    NidsChanged,
    /// The dirty set exceeded [`DeltaConfig::max_dirty_row_frac`].
    Threshold,
}

/// What one delta reroute refilled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Rows refilled completely (group structure or divider changed).
    pub rows_full: usize,
    /// Rows where only some destination-leaf blocks were refilled.
    pub rows_partial: usize,
    /// Rows proven unchanged and left untouched.
    pub rows_clean: usize,
    /// (switch, destination-leaf) blocks refilled inside partial rows.
    pub dirty_blocks: usize,
}

/// Outcome of a `reroute_delta_into` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaOutcome {
    /// The delta path applied: only the dirty rows were refilled.
    Delta(DeltaStats),
    /// Every row was refilled (a full reroute), for the given reason.
    Full(FallbackReason),
}

impl DeltaOutcome {
    /// True when the incremental path (not the full fallback) applied.
    pub fn is_delta(&self) -> bool {
        matches!(self, DeltaOutcome::Delta(_))
    }

    /// Dirty-set statistics when the incremental path applied.
    pub fn stats(&self) -> Option<DeltaStats> {
        match self {
            DeltaOutcome::Delta(st) => Some(*st),
            DeltaOutcome::Full(_) => None,
        }
    }
}

/// Pipeline products of the *previous* reroute, captured before the
/// rebuild overwrites the workspace buffers. All buffers are reused
/// across events (capture is `clear` + `extend_from_slice` — zero heap
/// allocation once capacities converge).
#[derive(Default)]
pub struct PrevProducts {
    valid: bool,
    had_isolated_leaf: bool,
    num_leaves: usize,
    leaves: Vec<SwitchId>,
    leaf_node_offsets: Vec<u32>,
    leaf_nodes: Vec<u32>,
    group_offsets: Vec<u32>,
    /// Packed `remote << 1 | up` per group, mirroring [`Prep::group_meta`].
    group_meta: Vec<u32>,
    port_offsets: Vec<u32>,
    ports: Vec<u16>,
    cost: Vec<u16>,
    divider: Vec<u64>,
    nids: Vec<u64>,
}

impl PrevProducts {
    /// Capture the products describing the workspace's last-routed
    /// topology.
    pub fn capture(&mut self, prep: &Prep, costs: &Costs, nids: &[u64]) {
        fn copy<T: Copy>(dst: &mut Vec<T>, src: &[T]) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        copy(&mut self.leaves, &prep.leaves);
        copy(&mut self.leaf_node_offsets, &prep.leaf_node_offsets);
        copy(&mut self.leaf_nodes, &prep.leaf_nodes);
        copy(&mut self.group_offsets, &prep.group_offsets);
        copy(&mut self.group_meta, &prep.group_meta);
        copy(&mut self.port_offsets, &prep.port_offsets);
        copy(&mut self.ports, &prep.ports);
        copy(&mut self.cost, &costs.cost);
        copy(&mut self.divider, &costs.divider);
        copy(&mut self.nids, nids);
        self.num_leaves = costs.num_leaves;
        self.had_isolated_leaf = prep
            .leaves
            .iter()
            .any(|&l| prep.up_groups[l as usize] == 0);
        self.valid = true;
    }

    /// Mark the history unusable (next delta call falls back).
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    /// Whether a capture is live (the diff has a baseline).
    pub fn is_valid(&self) -> bool {
        self.valid
    }

    /// Copy `other`'s captured products into this buffer set, reusing
    /// every allocation (`Vec::clone_from`) — the snapshot-restore hot
    /// path (`RerouteWorkspace::restore_from`) runs this once per
    /// campaign sample, so it must be allocation-free once capacities
    /// have converged.
    pub fn assign_from(&mut self, other: &PrevProducts) {
        // Exhaustive destructuring on purpose: adding a `PrevProducts`
        // field without deciding its restore semantics fails to compile
        // here instead of silently leaking the previous sample's state
        // into a restored baseline.
        let PrevProducts {
            valid,
            had_isolated_leaf,
            num_leaves,
            leaves,
            leaf_node_offsets,
            leaf_nodes,
            group_offsets,
            group_meta,
            port_offsets,
            ports,
            cost,
            divider,
            nids,
        } = other;
        self.valid = *valid;
        self.had_isolated_leaf = *had_isolated_leaf;
        self.num_leaves = *num_leaves;
        self.leaves.clone_from(leaves);
        self.leaf_node_offsets.clone_from(leaf_node_offsets);
        self.leaf_nodes.clone_from(leaf_nodes);
        self.group_offsets.clone_from(group_offsets);
        self.group_meta.clone_from(group_meta);
        self.port_offsets.clone_from(port_offsets);
        self.ports.clone_from(ports);
        self.cost.clone_from(cost);
        self.divider.clone_from(divider);
        self.nids.clone_from(nids);
    }
}

/// Pre-fill eligibility: reasons the dirty set cannot be bounded at
/// all. `None` means the per-row diff ([`DirtySet::compute`]) is sound.
///
/// Note the unit of comparison is the **row index**, not the physical
/// switch: the route fill is a pure function of index-level products,
/// so index-level equality is exactly what bit-identical tables need —
/// even in the contrived case where two different dead-switch sets of
/// equal size produce coincidentally identical products. Consumers
/// keyed by hardware identity (the UUID-keyed upload store) must
/// additionally gate on switch-set-preserving events, as
/// `FabricManager` does (delta tier = cable events only).
pub fn eligibility(
    prev: &PrevProducts,
    prep: &Prep,
    costs: &Costs,
    nids: &[u64],
) -> Option<FallbackReason> {
    if !prev.valid {
        return Some(FallbackReason::NoHistory);
    }
    // Row indices are only comparable when the switch compaction, the
    // leaf set, and the per-leaf node lists (ids *and* port-rank order)
    // are identical between the two topologies.
    if prev.group_offsets.len() != prep.group_offsets.len()
        || prev.leaves != prep.leaves
        || prev.leaf_node_offsets != prep.leaf_node_offsets
        || prev.leaf_nodes != prep.leaf_nodes
        || prev.num_leaves != costs.num_leaves
        || prev.cost.len() != costs.cost.len()
        || prev.divider.len() != costs.divider.len()
    {
        return Some(FallbackReason::ShapeChanged);
    }
    if prev.had_isolated_leaf
        || prep
            .leaves
            .iter()
            .any(|&l| prep.up_groups[l as usize] == 0)
    {
        return Some(FallbackReason::IsolatedLeaf);
    }
    if prev.nids[..] != nids[..] {
        return Some(FallbackReason::NidsChanged);
    }
    None
}

/// The dirty set of one delta reroute: which rows need a full refill,
/// which need only some destination-leaf blocks, and which are proven
/// clean. Bitsets are reused across events.
#[derive(Default)]
pub struct DirtySet {
    /// Leaves per row bitset word count.
    words: usize,
    num_rows: usize,
    /// Per-switch "own cost row changed at leaf li" bits (ns × words).
    cost_changed: Vec<u64>,
    /// Per-switch dirty destination-leaf bits (ns × words): own cost
    /// row or any group remote's cost row changed at that leaf.
    bits: Vec<u64>,
    /// Whole row must be refilled (groups or divider changed).
    full: Vec<bool>,
    /// Row has any dirty block (or is full-dirty).
    any: Vec<bool>,
}

impl DirtySet {
    /// Diff the new products against `prev` and derive the dirty set.
    /// Preconditions: [`eligibility`] returned `None`.
    pub fn compute(&mut self, prev: &PrevProducts, prep: &Prep, costs: &Costs) -> DeltaStats {
        let ns = prep.group_offsets.len() - 1;
        let nl = prep.leaves.len();
        self.words = nl.div_ceil(64);
        self.num_rows = ns;
        self.cost_changed.clear();
        self.cost_changed.resize(ns * self.words, 0);
        self.bits.clear();
        self.bits.resize(ns * self.words, 0);
        self.full.clear();
        self.full.resize(ns, false);
        self.any.clear();
        self.any.resize(ns, false);

        // Pass 1: per-switch structural diff + own-cost-row diff.
        for s in 0..ns {
            self.full[s] = Self::groups_changed(prev, prep, s)
                || costs.divider[s] != prev.divider[s];
            let new_row = &costs.cost[s * nl..(s + 1) * nl];
            let old_row = &prev.cost[s * nl..(s + 1) * nl];
            let w0 = s * self.words;
            for (li, (a, b)) in new_row.iter().zip(old_row).enumerate() {
                if a != b {
                    self.cost_changed[w0 + li / 64] |= 1u64 << (li % 64);
                }
            }
        }

        // Pass 2: a row is dirty at leaf li when its own cost row or
        // any group remote's cost row changed there (equation (1)
        // compares exactly those two cost values per group).
        let mut stats = DeltaStats::default();
        for s in 0..ns {
            let w0 = s * self.words;
            if self.full[s] {
                self.any[s] = true;
                stats.rows_full += 1;
                continue;
            }
            let (bits, changed) = (&mut self.bits, &self.cost_changed);
            bits[w0..w0 + self.words].copy_from_slice(&changed[w0..w0 + self.words]);
            for g in prep.group_offsets[s] as usize..prep.group_offsets[s + 1] as usize {
                let r = prep.group_remote(g) as usize;
                let rw0 = r * self.words;
                for w in 0..self.words {
                    bits[w0 + w] |= changed[rw0 + w];
                }
            }
            let blocks: u32 = bits[w0..w0 + self.words]
                .iter()
                .map(|w| w.count_ones())
                .sum();
            if blocks > 0 {
                self.any[s] = true;
                stats.rows_partial += 1;
                stats.dirty_blocks += blocks as usize;
            } else {
                stats.rows_clean += 1;
            }
        }
        stats
    }

    /// True when the port-group structure of switch `s` (remote ids,
    /// per-group port lists, group order) differs from the previous
    /// topology.
    fn groups_changed(prev: &PrevProducts, prep: &Prep, s: usize) -> bool {
        let (n0, n1) = (
            prep.group_offsets[s] as usize,
            prep.group_offsets[s + 1] as usize,
        );
        let (p0, p1) = (
            prev.group_offsets[s] as usize,
            prev.group_offsets[s + 1] as usize,
        );
        if n1 - n0 != p1 - p0 {
            return true;
        }
        // Packed compare covers remote ids *and* up flags; a flipped up
        // bit at equal remote can't happen without a level change (which
        // trips ShapeChanged first), so this is at worst conservative.
        if prep.group_meta[n0..n1] != prev.group_meta[p0..p1] {
            return true;
        }
        for (gn, gp) in (n0..n1).zip(p0..p1) {
            let new_ports = &prep.ports
                [prep.port_offsets[gn] as usize..prep.port_offsets[gn + 1] as usize];
            let old_ports =
                &prev.ports[prev.port_offsets[gp] as usize..prev.port_offsets[gp + 1] as usize];
            if new_ports != old_ports {
                return true;
            }
        }
        false
    }

    /// Rows touched by the delta fill (full + partial), ascending.
    pub fn touched_rows(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.num_rows as u32).filter(|&s| self.any[s as usize])
    }

    /// Whole-row refill needed.
    #[inline]
    pub fn row_full(&self, s: usize) -> bool {
        self.full[s]
    }

    /// Any block of row `s` dirty.
    #[inline]
    pub fn row_any(&self, s: usize) -> bool {
        self.any[s]
    }

    /// Dirty destination-leaf indices of a partial row, ascending.
    pub fn cols(&self, s: usize) -> impl Iterator<Item = u32> + '_ {
        let w0 = s * self.words;
        self.bits[w0..w0 + self.words]
            .iter()
            .enumerate()
            .flat_map(|(w, &bits)| {
                let mut rest = bits;
                std::iter::from_fn(move || {
                    if rest == 0 {
                        return None;
                    }
                    let b = rest.trailing_zeros();
                    rest &= rest - 1;
                    Some(w as u32 * 64 + b)
                })
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::common;
    use crate::topology::degrade;
    use crate::topology::pgft::PgftParams;
    use std::collections::HashSet;

    fn products(t: &crate::topology::Topology) -> (Prep, Costs, Vec<u64>) {
        let prep = Prep::new(t);
        let costs = common::costs(t, &prep, common::DividerReduction::Max);
        let nids = crate::routing::dmodc::topological_nids(t, &prep, &costs);
        (prep, costs, nids)
    }

    #[test]
    fn identical_topology_is_fully_clean() {
        let t = PgftParams::fig1().build();
        let (prep, costs, nids) = products(&t);
        let mut prev = PrevProducts::default();
        prev.capture(&prep, &costs, &nids);
        assert!(eligibility(&prev, &prep, &costs, &nids).is_none());
        let mut dirty = DirtySet::default();
        let st = dirty.compute(&prev, &prep, &costs);
        assert_eq!(st.rows_full, 0);
        assert_eq!(st.rows_partial, 0);
        assert_eq!(st.rows_clean, t.switches.len());
        assert_eq!(dirty.touched_rows().count(), 0);
    }

    #[test]
    fn parallel_cable_fault_dirties_exactly_the_endpoints() {
        // fig1 leaves have 2 parallel links per mid: removing one keeps
        // the group (costs, dividers, NIDs unchanged) and only the two
        // endpoint switches' port lists change.
        let t = PgftParams::fig1().build();
        let (prep, costs, nids) = products(&t);
        let mut prev = PrevProducts::default();
        prev.capture(&prep, &costs, &nids);
        let cable = degrade::cables(&t)[0]; // (leaf 0, port 0): parallel pair
        let dead: HashSet<(u32, u16)> = [cable].into_iter().collect();
        let d = degrade::apply(&t, &HashSet::new(), &dead);
        let (dprep, dcosts, dnids) = products(&d);
        assert!(eligibility(&prev, &dprep, &dcosts, &dnids).is_none());
        let mut dirty = DirtySet::default();
        let st = dirty.compute(&prev, &dprep, &dcosts);
        assert_eq!(st.rows_full, 2, "both cable endpoints");
        assert_eq!(st.rows_partial, 0);
        assert_eq!(st.rows_clean, t.switches.len() - 2);
    }

    #[test]
    fn no_history_and_shape_changes_fall_back() {
        let t = PgftParams::fig1().build();
        let (prep, costs, nids) = products(&t);
        let prev = PrevProducts::default();
        assert_eq!(
            eligibility(&prev, &prep, &costs, &nids),
            Some(FallbackReason::NoHistory)
        );
        let mut prev = PrevProducts::default();
        prev.capture(&prep, &costs, &nids);
        // Removing a spine changes the switch compaction.
        let dead: HashSet<u32> = [t.switches.len() as u32 - 1].into_iter().collect();
        let d = degrade::apply(&t, &dead, &HashSet::new());
        let (dprep, dcosts, dnids) = products(&d);
        assert_eq!(
            eligibility(&prev, &dprep, &dcosts, &dnids),
            Some(FallbackReason::ShapeChanged)
        );
    }

    #[test]
    fn isolated_leaf_falls_back_in_both_directions() {
        let t = PgftParams::fig1().build();
        let (prep, costs, nids) = products(&t);
        // Kill every uplink cable of leaf 0.
        let dead: HashSet<(u32, u16)> = degrade::cables(&t)
            .into_iter()
            .filter(|&(s, _)| s == t.leaf_switches()[0])
            .collect();
        assert!(!dead.is_empty());
        let d = degrade::apply(&t, &HashSet::new(), &dead);
        let (dprep, dcosts, dnids) = products(&d);
        // Fault direction: new side has an uplink-less leaf.
        let mut prev = PrevProducts::default();
        prev.capture(&prep, &costs, &nids);
        assert_eq!(
            eligibility(&prev, &dprep, &dcosts, &dnids),
            Some(FallbackReason::IsolatedLeaf)
        );
        // Recovery direction: the *previous* side had it.
        let mut prev = PrevProducts::default();
        prev.capture(&dprep, &dcosts, &dnids);
        assert_eq!(
            eligibility(&prev, &prep, &costs, &nids),
            Some(FallbackReason::IsolatedLeaf)
        );
    }

    #[test]
    fn cols_iterates_set_bits_in_order() {
        let t = PgftParams::small().build();
        let (prep, costs, nids) = products(&t);
        let mut prev = PrevProducts::default();
        prev.capture(&prep, &costs, &nids);
        // Kill the only link of a single-cable group (mid→top in small
        // has p3 = 1): costs change, producing partial rows.
        let mid = t
            .switches
            .iter()
            .position(|s| s.level == 1)
            .unwrap() as u32;
        let cable = degrade::cables(&t)
            .into_iter()
            .find(|&(s, _)| s == mid)
            .unwrap();
        let dead: HashSet<(u32, u16)> = [cable].into_iter().collect();
        let d = degrade::apply(&t, &HashSet::new(), &dead);
        let (dprep, dcosts, dnids) = products(&d);
        if eligibility(&prev, &dprep, &dcosts, &dnids).is_some() {
            return; // NIDs shifted on this shape; nothing to iterate
        }
        let mut dirty = DirtySet::default();
        let st = dirty.compute(&prev, &dprep, &dcosts);
        let mut seen_blocks = 0usize;
        for s in 0..d.switches.len() {
            if dirty.row_full(s) || !dirty.row_any(s) {
                continue;
            }
            let cols: Vec<u32> = dirty.cols(s).collect();
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "ascending");
            assert!(cols.iter().all(|&li| (li as usize) < dprep.leaves.len()));
            seen_blocks += cols.len();
        }
        assert_eq!(seen_blocks, st.dirty_blocks);
    }
}
