//! Load-adaptive **SSSP** routing (Hoefler's scheme, OpenSM's (DF)SSSP
//! without the virtual-lane assignment — the paper's analysis explicitly
//! ignores virtual channels).
//!
//! Destinations are routed one by one: a Dijkstra from the destination's
//! leaf over edge weights `1 + load(port)` picks, for every switch, the
//! cheapest egress; afterwards the load of every used port is increased by
//! the number of source *nodes* whose route crosses it, so later
//! destinations avoid hot links. Topology-agnostic: no level or up/down
//! information is used at all, which is what makes it the most robust
//! baseline under massive degradation (and among the slowest — Figure 3).

use super::common::{Prep, PrepScratch};
use super::engine::{Capabilities, RoutingEngine};
use super::{Lft, NO_ROUTE};
use crate::topology::Topology;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Persistent buffers for repeated SSSP reroutes: CSR prep, the per-port
/// load accumulators, and the per-destination Dijkstra state.
#[derive(Default)]
pub struct Workspace {
    prep: Prep,
    prep_scratch: PrepScratch,
    load: Vec<u64>,
    nodes_on: Vec<u64>,
    dist: Vec<u64>,
    egress: Vec<u16>,
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    order: Vec<u32>,
    acc: Vec<u64>,
}

/// SSSP into reused buffers (allocation-free in steady state).
pub fn route_into(topo: &Topology, ws: &mut Workspace, out: &mut Lft) {
    Prep::build_into(topo, &mut ws.prep, &mut ws.prep_scratch);
    let Workspace {
        prep,
        load,
        nodes_on,
        dist,
        egress,
        heap,
        order,
        acc,
        ..
    } = ws;
    let ns = topo.switches.len();
    out.reset(ns, topo.nodes.len());
    load.clear();
    load.resize(topo.num_ports(), 0);

    // Nodes attached per switch (route-usage accumulation weights).
    nodes_on.clear();
    nodes_on.resize(ns, 0);
    for n in &topo.nodes {
        nodes_on[n.leaf as usize] += 1;
    }

    dist.clear();
    dist.resize(ns, u64::MAX);
    egress.clear();
    egress.resize(ns, NO_ROUTE);
    for d in 0..topo.nodes.len() as u32 {
        let node = topo.nodes[d as usize];
        let leaf = node.leaf;
        dist.fill(u64::MAX);
        egress.fill(NO_ROUTE);
        dist[leaf as usize] = 0;
        out.set(leaf, d, node.leaf_port);

        heap.clear();
        heap.push(Reverse((0, leaf)));
        order.clear();
        while let Some(Reverse((dv, s))) = heap.pop() {
            if dv > dist[s as usize] {
                continue;
            }
            order.push(s);
            // Relax: a neighbor r would route *into* s through r's port.
            for g in prep.groups(s as usize) {
                let r = g.remote;
                // r's ports toward s are the mirror of g; find r's cheapest.
                for &p_here in g.ports {
                    // The remote end of (s, p_here):
                    if let crate::topology::PortTarget::Switch { rport, .. } =
                        topo.switches[s as usize].ports[p_here as usize]
                    {
                        let pid_r = topo.port_id(r, rport) as usize;
                        let w = 1 + load[pid_r];
                        let nd = dv + w;
                        if nd < dist[r as usize] {
                            dist[r as usize] = nd;
                            egress[r as usize] = rport;
                            heap.push(Reverse((nd, r)));
                        }
                    }
                }
            }
        }
        // Accumulate per-port usage: process switches farthest-first and
        // push source-node counts down the parent pointers.
        acc.clear();
        acc.extend_from_slice(nodes_on);
        acc[leaf as usize] = acc[leaf as usize].saturating_sub(1); // d itself
        for &s in order.iter().rev() {
            let su = s as usize;
            if s == leaf || egress[su] == NO_ROUTE {
                continue;
            }
            out.set(s, d, egress[su]);
            if acc[su] > 0 {
                load[topo.port_id(s, egress[su]) as usize] += acc[su];
                if let crate::topology::PortTarget::Switch { sw: next, .. } =
                    topo.switches[su].ports[egress[su] as usize]
                {
                    acc[next as usize] += acc[su];
                }
            }
        }
    }
}

/// One-shot wrapper over [`route_into`] with a fresh [`Workspace`].
pub fn route(topo: &Topology) -> Lft {
    let mut ws = Workspace::default();
    let mut out = Lft::default();
    route_into(topo, &mut ws, &mut out);
    out
}

/// The stateful SSSP [`RoutingEngine`]. Load accumulators are reset per
/// reroute, so the engine stays deterministic and history-free.
#[derive(Default)]
pub struct Engine {
    ws: Workspace,
}

impl RoutingEngine for Engine {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            deterministic_history_free: true,
            ..Capabilities::default()
        }
    }

    fn route_into(&mut self, topo: &Topology, out: &mut Lft) {
        route_into(topo, &mut self.ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::validity;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn intact_pgft_valid() {
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        validity::check(&t, &lft).unwrap();
    }

    #[test]
    fn robust_under_massive_degradation() {
        use crate::topology::degrade;
        use crate::util::rng::Rng;
        let t = PgftParams::small().build();
        let mut rng = Rng::new(55);
        // Remove half of all cables; SSSP must still route every pair that
        // remains connected (validity may fail, but traces must not loop).
        let dt = degrade::remove_random_links(&t, &mut rng, t.num_cables() / 2);
        let lft = route(&dt);
        let st = validity::stats(&dt, &lft);
        assert_eq!(
            st.routes + st.unreachable,
            dt.leaf_switches().len() * dt.nodes.len() - dt.nodes.len()
        );
    }

    #[test]
    fn load_spreading_differs_from_single_path() {
        // With per-destination load updates, consecutive destinations on
        // the same remote leaf should not all share one spine.
        let t = PgftParams::fig1().build();
        let lft = route(&t);
        let leaf = t.leaf_switches()[0];
        let remote: Vec<u32> = (0..t.nodes.len() as u32)
            .filter(|&d| t.nodes[d as usize].leaf != leaf)
            .collect();
        let ports: std::collections::HashSet<u16> =
            remote.iter().map(|&d| lft.get(leaf, d)).collect();
        assert!(ports.len() > 1, "SSSP should spread uplinks");
    }

    // Engine-vs-free-function bit-identity across workspace reuse is
    // covered for all engines by tests/equivalence.rs
    // (engines_bit_identical_to_free_functions_across_reuse).
}
