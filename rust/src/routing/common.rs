//! Shared routing preprocessing: ranks, port groups, and the cost/divider
//! sweeps of the paper's Algorithm 1.
//!
//! Data layout (EXPERIMENTS.md §Perf): port groups live in a CSR-style flat
//! layout — `group_offsets` indexes a switch's groups, `port_offsets`
//! indexes each group's ports in one flat `ports` array — instead of the
//! original `Vec<Vec<Group>>`-of-`Vec<u16>` nesting. The routing hot loops
//! stream these arrays O(switches × leaves) times per reroute; the flat
//! layout removes two pointer chases per group visit and lets
//! [`Prep::build_into`] rebuild the whole structure without allocating in
//! the fault-storm steady state.
//!
//! Algorithm 1 ([`costs`]) is parallelized level-synchronously: all
//! switches within one level are independent, so each level is one
//! parallel step over `by_level_up` (see `costs_into`); the sweeps *pull*
//! from neighbor rows finalized in earlier levels, which keeps the result
//! bit-identical to the serial push formulation retained in
//! [`costs_serial`].

use crate::topology::{NodeId, PortTarget, SwitchId, Topology};
use crate::util::par::{grain, parallel_for_chunked, SharedMut};
use std::cell::RefCell;

/// Unreachable cost sentinel.
pub const INF: u16 = u16::MAX;

/// Leaf-index tile width for the cost-row relaxations: the write row tile
/// (u16 × 1024 = 2 KiB) stays L1-resident while every neighbor row streams
/// through it once per level.
const COST_TILE: usize = 1024;

/// A borrowed view of one port group: all ports of a switch linked to the
/// same remote switch (the paper prepares these sorted by remote UUID "to
/// help with same-destination route coalescing").
#[derive(Clone, Copy, Debug)]
pub struct GroupRef<'a> {
    pub remote: SwitchId,
    /// True if `remote` is at a higher level (uplink group).
    pub up: bool,
    /// Local port indices, ascending.
    pub ports: &'a [u16],
}

/// Preprocessed view of a topology shared by the routing engines.
///
/// Rebuildable in place via [`Prep::build_into`] (allocation-free once the
/// buffers have grown to the topology's size).
#[derive(Default)]
pub struct Prep {
    /// Leaf switches, ascending id.
    pub leaves: Vec<SwitchId>,
    /// switch id -> index into `leaves` (or `u32::MAX`).
    pub leaf_index: Vec<u32>,
    /// CSR: groups of switch `s` are `group_offsets[s]..group_offsets[s+1]`
    /// into `group_meta` / `port_offsets`.
    pub group_offsets: Vec<u32>,
    /// Per group: remote switch id and uplink flag packed as
    /// `remote << 1 | up` (UUID-sorted within a switch). One u32 instead
    /// of the former `Vec<SwitchId>` + `Vec<bool>` pair: the hot loops
    /// always read both together, and the packed layout halves the bytes
    /// streamed per group visit (decode via [`Prep::group_remote`] /
    /// [`Prep::group_is_up`]). Switch ids stay well under 2^31.
    pub group_meta: Vec<u32>,
    /// CSR: ports of group `g` are `port_offsets[g]..port_offsets[g+1]`
    /// into `ports`.
    pub port_offsets: Vec<u32>,
    /// Flat local port indices, ascending within each group.
    pub ports: Vec<u16>,
    /// Per switch: number of uplink groups (`#{s' ⊃ s}` in the paper).
    pub up_groups: Vec<u32>,
    /// Switch ids sorted by ascending level (stable by id).
    pub by_level_up: Vec<SwitchId>,
    /// Level `l` spans `by_level_up[level_offsets[l]..level_offsets[l+1]]`.
    pub level_offsets: Vec<u32>,
    /// CSR: nodes of leaf-index `li` (port-rank order) are
    /// `leaf_node_offsets[li]..leaf_node_offsets[li+1]` into `leaf_nodes`.
    pub leaf_node_offsets: Vec<u32>,
    pub leaf_nodes: Vec<NodeId>,
    /// [`Topology::fingerprint`] of the topology this `Prep` was built
    /// for (0 = never built). Lets cached-product consumers
    /// (`validity::check_with`) reject stale preprocessing that merely
    /// *shapes* like the topology at hand.
    pub topo_fingerprint: u64,
}

/// Reusable staging buffers for [`Prep::build_into`].
#[derive(Default)]
pub struct PrepScratch {
    /// Per-switch first-port offset into `ports` (prefix-summed counts).
    port_base: Vec<u32>,
    cursor: Vec<u32>,
}

/// Per-worker staging for the parallel CSR build: one switch's groups in
/// first-encounter order before the UUID sort. Thread-local because the
/// chunked claims hand switches to arbitrary workers; each vector is
/// reserved to the topology-wide port bound on first touch, after which
/// rebuilds are allocation-free on every pool thread.
#[derive(Default)]
struct BuildStage {
    remotes: Vec<SwitchId>,
    counts: Vec<u32>,
    order: Vec<u32>,
    dst: Vec<u32>,
}

impl BuildStage {
    fn reserve(&mut self, max_ports: usize) {
        self.remotes.reserve(max_ports);
        self.counts.reserve(max_ports);
        self.order.reserve(max_ports);
        self.dst.reserve(max_ports);
    }
}

thread_local! {
    static BUILD_STAGE: RefCell<BuildStage> = RefCell::new(BuildStage::default());
}

impl Prep {
    pub fn new(topo: &Topology) -> Self {
        let mut out = Prep::default();
        let mut scratch = PrepScratch::default();
        Prep::build_into(topo, &mut out, &mut scratch);
        out
    }

    /// Rebuild `out` for `topo`, reusing every buffer (and `scratch`)
    /// from previous builds — zero heap allocation in steady state.
    ///
    /// The CSR construction runs in two parallel passes over switches with
    /// one serial prefix sum between them: pass A counts each switch's
    /// distinct groups and switch-link ports, the prefix sums turn the
    /// counts into `group_offsets` / per-switch port bases, and pass B
    /// writes each switch's `group_meta` / `port_offsets` / `ports` range.
    /// Every output slot's position and value is a pure per-switch function
    /// of the topology, so the result is bit-identical to a serial build
    /// at every thread count regardless of chunk claim order.
    pub fn build_into(topo: &Topology, out: &mut Prep, scratch: &mut PrepScratch) {
        let ns = topo.switches.len();
        let max_ports = topo.switches.iter().map(|sw| sw.ports.len()).max().unwrap_or(0);

        out.leaves.clear();
        out.leaves
            .extend((0..ns as SwitchId).filter(|&s| topo.switches[s as usize].level == 0));
        out.leaf_index.clear();
        out.leaf_index.resize(ns, u32::MAX);
        for (i, &l) in out.leaves.iter().enumerate() {
            out.leaf_index[l as usize] = i as u32;
        }

        // Pass A: per-switch group/port counts into slot s+1 (disjoint).
        out.group_offsets.clear();
        out.group_offsets.resize(ns + 1, 0);
        scratch.port_base.clear();
        scratch.port_base.resize(ns + 1, 0);
        {
            let group_counts = SharedMut::new(&mut out.group_offsets);
            let port_counts = SharedMut::new(&mut scratch.port_base);
            let group_counts = &group_counts;
            let port_counts = &port_counts;
            parallel_for_chunked(ns, grain(ns, 8), |s| {
                BUILD_STAGE.with(|st| {
                    let st = &mut *st.borrow_mut();
                    st.reserve(max_ports);
                    st.remotes.clear();
                    let mut np = 0u32;
                    for p in &topo.switches[s].ports {
                        if let PortTarget::Switch { sw: r, .. } = *p {
                            np += 1;
                            if !st.remotes.contains(&r) {
                                st.remotes.push(r);
                            }
                        }
                    }
                    // SAFETY: each task writes only slot s+1 of each array.
                    unsafe {
                        *group_counts.get_mut(s + 1) = st.remotes.len() as u32;
                        *port_counts.get_mut(s + 1) = np;
                    }
                });
            });
        }
        for s in 0..ns {
            out.group_offsets[s + 1] += out.group_offsets[s];
            scratch.port_base[s + 1] += scratch.port_base[s];
        }
        let total_groups = out.group_offsets[ns] as usize;
        let total_ports = scratch.port_base[ns] as usize;
        out.group_meta.clear();
        out.group_meta.resize(total_groups, 0);
        out.port_offsets.clear();
        out.port_offsets.resize(total_groups + 1, 0);
        out.ports.clear();
        out.ports.resize(total_ports, 0);
        out.up_groups.clear();
        out.up_groups.resize(ns, 0);

        // Pass B: each switch fills its own (disjoint) CSR ranges.
        {
            let group_meta = SharedMut::new(&mut out.group_meta);
            let port_offsets = SharedMut::new(&mut out.port_offsets);
            let ports_out = SharedMut::new(&mut out.ports);
            let up_groups = SharedMut::new(&mut out.up_groups);
            let group_meta = &group_meta;
            let port_offsets = &port_offsets;
            let ports_out = &ports_out;
            let up_groups = &up_groups;
            let group_offsets = &out.group_offsets;
            let port_base = &scratch.port_base;
            parallel_for_chunked(ns, grain(ns, 8), |s| {
                BUILD_STAGE.with(|st| {
                    let st = &mut *st.borrow_mut();
                    st.reserve(max_ports);
                    // Stage groups in first-encounter port order.
                    st.remotes.clear();
                    st.counts.clear();
                    for p in &topo.switches[s].ports {
                        if let PortTarget::Switch { sw: r, .. } = *p {
                            if let Some(g) = st.remotes.iter().position(|&x| x == r) {
                                st.counts[g] += 1;
                            } else {
                                st.remotes.push(r);
                                st.counts.push(1);
                            }
                        }
                    }
                    let ng = st.remotes.len();
                    // Emit in remote-UUID order (UUIDs are unique, so this
                    // equals the original stable sort).
                    st.order.clear();
                    st.order.extend(0..ng as u32);
                    let remotes = &st.remotes;
                    st.order.sort_unstable_by_key(|&g| {
                        topo.switches[remotes[g as usize] as usize].uuid
                    });
                    st.dst.clear();
                    st.dst.resize(ng, 0);
                    let g0 = group_offsets[s] as usize;
                    let mut cursor = port_base[s];
                    let mut upg = 0u32;
                    for (k, &g) in st.order.iter().enumerate() {
                        let r = st.remotes[g as usize];
                        // Same-level links are rejected by
                        // `check_invariants`, but `Topology` fields are
                        // public — enforce the precondition here because
                        // the level-synchronous sweeps of `costs_into`
                        // rely on every link crossing levels (their
                        // per-level write-disjointness argument is unsound
                        // otherwise).
                        assert_ne!(
                            topo.switches[r as usize].level,
                            topo.switches[s].level,
                            "same-level link between switches {s} and {r} (invalid topology)"
                        );
                        let up = topo.switches[r as usize].level > topo.switches[s].level;
                        if up {
                            upg += 1;
                        }
                        st.dst[g as usize] = cursor;
                        cursor += st.counts[g as usize];
                        // SAFETY: group slots g0..g0+ng and port_offsets
                        // slots g0+1..=g0+ng belong to switch s alone
                        // (slot g0 is the previous switch's final entry;
                        // slot 0 stays the serial-initialized 0).
                        unsafe {
                            *group_meta.get_mut(g0 + k) = (r << 1) | up as u32;
                            *port_offsets.get_mut(g0 + k + 1) = cursor;
                        }
                    }
                    // Second port scan writes each group's ports ascending.
                    for (pi, p) in topo.switches[s].ports.iter().enumerate() {
                        if let PortTarget::Switch { sw: r, .. } = *p {
                            let g = st.remotes.iter().position(|&x| x == r).unwrap();
                            // SAFETY: this switch's port range
                            // `port_base[s]..port_base[s+1]` is disjoint
                            // from every other switch's.
                            unsafe {
                                *ports_out.get_mut(st.dst[g] as usize) = pi as u16;
                            }
                            st.dst[g] += 1;
                        }
                    }
                    // SAFETY: slot s is this task's alone.
                    unsafe {
                        *up_groups.get_mut(s) = upg;
                    }
                });
            });
        }

        // by_level_up + level_offsets via counting sort (stable by id).
        let nlv = topo.num_levels as usize;
        out.level_offsets.clear();
        out.level_offsets.resize(nlv + 1, 0);
        for sw in &topo.switches {
            out.level_offsets[sw.level as usize + 1] += 1;
        }
        for l in 0..nlv {
            out.level_offsets[l + 1] += out.level_offsets[l];
        }
        out.by_level_up.clear();
        out.by_level_up.resize(ns, 0);
        scratch.cursor.clear();
        scratch
            .cursor
            .extend_from_slice(&out.level_offsets[..nlv]);
        for (s, sw) in topo.switches.iter().enumerate() {
            let c = &mut scratch.cursor[sw.level as usize];
            out.by_level_up[*c as usize] = s as SwitchId;
            *c += 1;
        }

        // Per-leaf node lists (port-rank order — ports iterate ascending).
        out.leaf_node_offsets.clear();
        out.leaf_nodes.clear();
        out.leaf_node_offsets.push(0);
        for &l in &out.leaves {
            for p in &topo.switches[l as usize].ports {
                if let PortTarget::Node { node } = *p {
                    out.leaf_nodes.push(node);
                }
            }
            out.leaf_node_offsets.push(out.leaf_nodes.len() as u32);
        }

        out.topo_fingerprint = topo.fingerprint();
    }

    /// Number of port groups of switch `s`.
    #[inline]
    pub fn num_groups(&self, s: usize) -> usize {
        (self.group_offsets[s + 1] - self.group_offsets[s]) as usize
    }

    /// The `gi`-th (UUID-ordered) group of switch `s`.
    #[inline]
    pub fn group(&self, s: usize, gi: usize) -> GroupRef<'_> {
        self.group_at(self.group_offsets[s] as usize + gi)
    }

    /// Remote switch of flat group `g` (decoded from `group_meta`).
    #[inline]
    pub fn group_remote(&self, g: usize) -> SwitchId {
        self.group_meta[g] >> 1
    }

    /// Uplink flag of flat group `g` (decoded from `group_meta`).
    #[inline]
    pub fn group_is_up(&self, g: usize) -> bool {
        self.group_meta[g] & 1 != 0
    }

    #[inline]
    fn group_at(&self, g: usize) -> GroupRef<'_> {
        let meta = self.group_meta[g];
        GroupRef {
            remote: meta >> 1,
            up: meta & 1 != 0,
            ports: &self.ports
                [self.port_offsets[g] as usize..self.port_offsets[g + 1] as usize],
        }
    }

    /// Iterate the UUID-ordered groups of switch `s`.
    #[inline]
    pub fn groups(&self, s: usize) -> GroupIter<'_> {
        GroupIter {
            prep: self,
            g: self.group_offsets[s] as usize,
            end: self.group_offsets[s + 1] as usize,
        }
    }

    /// Switches of one level, ascending id.
    #[inline]
    pub fn level_span(&self, lvl: usize) -> &[SwitchId] {
        &self.by_level_up
            [self.level_offsets[lvl] as usize..self.level_offsets[lvl + 1] as usize]
    }

    /// Number of levels covered by `level_offsets`.
    #[inline]
    pub fn num_levels(&self) -> usize {
        self.level_offsets.len().saturating_sub(1)
    }

    /// Nodes of leaf-index `li` in port-rank order (= per-leaf NID order).
    #[inline]
    pub fn nodes_of_leaf_idx(&self, li: u32) -> &[NodeId] {
        &self.leaf_nodes[self.leaf_node_offsets[li as usize] as usize
            ..self.leaf_node_offsets[li as usize + 1] as usize]
    }
}

/// Iterator over a switch's port groups.
pub struct GroupIter<'a> {
    prep: &'a Prep,
    g: usize,
    end: usize,
}

impl<'a> Iterator for GroupIter<'a> {
    type Item = GroupRef<'a>;

    #[inline]
    fn next(&mut self) -> Option<GroupRef<'a>> {
        if self.g == self.end {
            return None;
        }
        let out = self.prep.group_at(self.g);
        self.g += 1;
        Some(out)
    }

    #[inline]
    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.end - self.g;
        (n, Some(n))
    }
}

impl ExactSizeIterator for GroupIter<'_> {}

/// Divider reduction choice of Algorithm 1 (the paper uses `Max`; the
/// `FirstPath` variant is the alternative it reports as showing "little to
/// no change" — kept for the ablation bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DividerReduction {
    Max,
    FirstPath,
}

/// Output of the paper's Algorithm 1 plus the pure-down costs needed by
/// UPDN-style engines.
#[derive(Default)]
pub struct Costs {
    /// `c[s * num_leaves + li]`: min hops from switch `s` to leaf
    /// `leaves[li]` under up*/down* restriction.
    pub cost: Vec<u16>,
    /// Same layout, but down-moves only (the state after the upward sweep).
    pub down_cost: Vec<u16>,
    /// Divider Π per switch.
    pub divider: Vec<u64>,
    pub num_leaves: usize,
}

impl Costs {
    #[inline]
    pub fn cost(&self, s: SwitchId, leaf_idx: u32) -> u16 {
        self.cost[s as usize * self.num_leaves + leaf_idx as usize]
    }

    #[inline]
    pub fn down(&self, s: SwitchId, leaf_idx: u32) -> u16 {
        self.down_cost[s as usize * self.num_leaves + leaf_idx as usize]
    }
}

/// Algorithm 1: compute costs and dividers (parallel; see [`costs_into`]).
pub fn costs(topo: &Topology, prep: &Prep, reduction: DividerReduction) -> Costs {
    let mut out = Costs::default();
    costs_into(topo, prep, reduction, &mut out);
    out
}

/// Algorithm 1 into reused buffers, parallelized level-synchronously.
///
/// The serial formulation *pushes* relaxations from each switch (ascending
/// level) into its up-neighbors. Here each level is one parallel step in
/// which every switch of that level *pulls* from its down-neighbors —
/// whose rows were finalized in earlier steps — so tasks write only their
/// own cost row and divider slot (no write races) and `min`/`max` being
/// order-independent keeps the result bit-identical to [`costs_serial`]
/// for both [`DividerReduction`] variants at every thread count. The
/// downward sweep mirrors this, descending, pulling from up-neighbors.
///
/// Row relaxations are blocked into [`COST_TILE`]-wide leaf tiles so the
/// O(switches × leaves) sweeps stream neighbor rows through an L1-resident
/// write tile instead of thrashing the write row on every pass.
pub fn costs_into(topo: &Topology, prep: &Prep, reduction: DividerReduction, out: &mut Costs) {
    let ns = topo.switches.len();
    let nl = prep.leaves.len();
    out.num_leaves = nl;
    out.cost.clear();
    out.cost.resize(ns * nl, INF);
    out.divider.clear();
    out.divider.resize(ns, 1);
    for (li, &l) in prep.leaves.iter().enumerate() {
        out.cost[l as usize * nl + li] = 0;
    }
    let nlv = prep.num_levels();

    // Upward sweep: level-synchronous pull from down-neighbors.
    {
        let cost = SharedMut::new(&mut out.cost);
        let divider = SharedMut::new(&mut out.divider);
        let cost = &cost;
        let divider = &divider;
        for lvl in 1..nlv {
            let span = prep.level_span(lvl);
            // Chunked claims (a few per worker) amortize cursor traffic at
            // the wide levels while stragglers still steal; each item is a
            // whole cost row, so identity is claim-order independent.
            parallel_for_chunked(span.len(), grain(span.len(), 8), |i| {
                let r = span[i] as usize;
                // SAFETY: this task exclusively writes row r and
                // divider[r]; every read targets a strictly lower level,
                // finalized by the per-level barrier.
                let row = unsafe { cost.slice_mut(r * nl, nl) };
                // Divider reduction over down-neighbors s:
                // contribution π = Π_s · #upgroups(s).
                let mut pi = 1u64;
                match reduction {
                    DividerReduction::Max => {
                        for g in prep.groups(r) {
                            if g.up {
                                continue;
                            }
                            let s = g.remote as usize;
                            let contrib = unsafe { *divider.get(s) }
                                * prep.up_groups[s].max(1) as u64;
                            if contrib > pi {
                                pi = contrib;
                            }
                        }
                    }
                    DividerReduction::FirstPath => {
                        // The serial sweep's first writer is the
                        // down-neighbor earliest in (level, id) order.
                        let mut first: Option<(u8, SwitchId)> = None;
                        for g in prep.groups(r) {
                            if g.up {
                                continue;
                            }
                            let key =
                                (topo.switches[g.remote as usize].level, g.remote);
                            if first.is_none_or(|f| key < f) {
                                first = Some(key);
                                let s = g.remote as usize;
                                pi = unsafe { *divider.get(s) }
                                    * prep.up_groups[s].max(1) as u64;
                            }
                        }
                    }
                }
                unsafe {
                    *divider.get_mut(r) = pi;
                }
                // Cost relaxation, leaf-tile blocked.
                let mut t0 = 0;
                while t0 < nl {
                    let t1 = (t0 + COST_TILE).min(nl);
                    for g in prep.groups(r) {
                        if g.up {
                            continue;
                        }
                        let src = unsafe {
                            cost.slice(g.remote as usize * nl + t0, t1 - t0)
                        };
                        for (d, &s) in row[t0..t1].iter_mut().zip(src) {
                            let via = s.saturating_add(1);
                            if via < *d {
                                *d = via;
                            }
                        }
                    }
                    t0 = t1;
                }
            });
        }
    }

    out.down_cost.clear();
    out.down_cost.extend_from_slice(&out.cost);

    // Downward sweep: level-synchronous pull from up-neighbors.
    {
        let cost = SharedMut::new(&mut out.cost);
        let cost = &cost;
        for lvl in (0..nlv.saturating_sub(1)).rev() {
            let span = prep.level_span(lvl);
            parallel_for_chunked(span.len(), grain(span.len(), 8), |i| {
                let r = span[i] as usize;
                // SAFETY: exclusive write of row r; reads target strictly
                // higher levels, finalized by the per-level barrier.
                let row = unsafe { cost.slice_mut(r * nl, nl) };
                let mut t0 = 0;
                while t0 < nl {
                    let t1 = (t0 + COST_TILE).min(nl);
                    for g in prep.groups(r) {
                        if !g.up {
                            continue;
                        }
                        let src = unsafe {
                            cost.slice(g.remote as usize * nl + t0, t1 - t0)
                        };
                        for (d, &s) in row[t0..t1].iter_mut().zip(src) {
                            let via = s.saturating_add(1);
                            if via < *d {
                                *d = via;
                            }
                        }
                    }
                    t0 = t1;
                }
            });
        }
    }
}

/// The original serial push-based Algorithm 1, retained verbatim as the
/// reference implementation for the equivalence suite
/// (`tests/equivalence.rs` asserts [`costs`] is bit-identical to this on
/// intact and degraded topologies at every thread count).
pub fn costs_serial(topo: &Topology, prep: &Prep, reduction: DividerReduction) -> Costs {
    let ns = topo.switches.len();
    let nl = prep.leaves.len();
    let mut cost = vec![INF; ns * nl];
    let mut divider = vec![1u64; ns];
    let mut divider_set = vec![false; ns];
    for (li, &l) in prep.leaves.iter().enumerate() {
        cost[l as usize * nl + li] = 0;
    }
    // Upward sweep.
    for &s in &prep.by_level_up {
        let su = s as usize;
        let pi = divider[su] * prep.up_groups[su].max(1) as u64;
        for g in prep.groups(su) {
            if !g.up {
                continue;
            }
            let r = g.remote as usize;
            // Cost relaxation toward the up-neighbor.
            for li in 0..nl {
                let via = cost[su * nl + li].saturating_add(1);
                if via < cost[r * nl + li] {
                    cost[r * nl + li] = via;
                }
            }
            // Divider reduction.
            match reduction {
                DividerReduction::Max => {
                    if pi > divider[r] {
                        divider[r] = pi;
                    }
                }
                DividerReduction::FirstPath => {
                    if !divider_set[r] {
                        divider[r] = pi;
                        divider_set[r] = true;
                    }
                }
            }
        }
    }
    let down_cost = cost.clone();
    // Downward sweep.
    for &s in prep.by_level_up.iter().rev() {
        let su = s as usize;
        for g in prep.groups(su) {
            if g.up {
                continue;
            }
            let r = g.remote as usize;
            for li in 0..nl {
                let via = cost[su * nl + li].saturating_add(1);
                if via < cost[r * nl + li] {
                    cost[r * nl + li] = via;
                }
            }
        }
    }
    Costs {
        cost,
        down_cost,
        divider,
        num_leaves: nl,
    }
}

/// Plain BFS hop distances from `from` to every switch (undirected,
/// ignoring levels) — the MinHop metric.
pub fn bfs_dist(topo: &Topology, from: SwitchId) -> Vec<u16> {
    let ns = topo.switches.len();
    let mut dist = vec![INF; ns];
    let mut queue = std::collections::VecDeque::new();
    dist[from as usize] = 0;
    queue.push_back(from);
    while let Some(s) = queue.pop_front() {
        let d = dist[s as usize];
        for p in &topo.switches[s as usize].ports {
            if let PortTarget::Switch { sw: r, .. } = *p {
                if dist[r as usize] == INF {
                    dist[r as usize] = d + 1;
                    queue.push_back(r);
                }
            }
        }
    }
    dist
}

/// Derive ranks (levels) from scratch, as the paper's preprocessing does:
/// leaf switches (those with attached nodes) are level 0 and every other
/// switch gets its undirected BFS distance to the nearest leaf. On intact
/// and moderately-degraded PGFTs this equals the constructed level; it is
/// used by tests and by generic (non-PGFT) inputs.
pub fn derive_ranks(topo: &Topology) -> Vec<u8> {
    let ns = topo.switches.len();
    let mut rank = vec![u8::MAX; ns];
    let mut queue = std::collections::VecDeque::new();
    for (s, sw) in topo.switches.iter().enumerate() {
        if sw.ports.iter().any(|p| matches!(p, PortTarget::Node { .. })) {
            rank[s] = 0;
            queue.push_back(s as SwitchId);
        }
    }
    while let Some(s) = queue.pop_front() {
        let r = rank[s as usize];
        for p in &topo.switches[s as usize].ports {
            if let PortTarget::Switch { sw: n, .. } = *p {
                if rank[n as usize] == u8::MAX {
                    rank[n as usize] = r + 1;
                    queue.push_back(n);
                }
            }
        }
    }
    rank
}

/// The destination's leaf switch λ_d.
#[inline]
pub fn leaf_of(topo: &Topology, d: NodeId) -> SwitchId {
    topo.nodes[d as usize].leaf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn fig1_costs_structure() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        // Leaf to itself: 0; leaf to any other leaf: 2 (shared mid) or 4.
        for (li, &l) in prep.leaves.iter().enumerate() {
            for (lj, &l2) in prep.leaves.iter().enumerate() {
                let v = c.cost(l, lj as u32);
                if li == lj {
                    assert_eq!(v, 0);
                } else {
                    assert!(v == 2 || v == 4, "leaf {l}->{l2} cost {v}");
                }
            }
        }
    }

    #[test]
    fn fig1_dividers() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        for (s, sw) in t.switches.iter().enumerate() {
            let expect = match sw.level {
                0 => 1,
                1 => 2, // leaf up-groups = w2 = 2
                2 => 4, // 2 * w3 = 2*2
                _ => unreachable!(),
            };
            assert_eq!(c.divider[s], expect, "switch {s} level {}", sw.level);
        }
    }

    #[test]
    fn down_cost_is_infinite_upward() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        // From a leaf, pure-down cost to a different leaf is INF.
        let l0 = prep.leaves[0];
        assert_eq!(c.down(l0, 1), INF);
        assert_eq!(c.down(l0, 0), 0);
    }

    #[test]
    fn cost_upper_bounds_down_cost() {
        let t = PgftParams::small().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        let nl = prep.leaves.len();
        for s in 0..t.switches.len() {
            for li in 0..nl {
                assert!(c.cost[s * nl + li] <= c.down_cost[s * nl + li]);
            }
        }
    }

    #[test]
    fn parallel_costs_match_serial_reference() {
        for params in [PgftParams::fig1(), PgftParams::small()] {
            let t = params.build();
            let prep = Prep::new(&t);
            for reduction in [DividerReduction::Max, DividerReduction::FirstPath] {
                let par = costs(&t, &prep, reduction);
                let ser = costs_serial(&t, &prep, reduction);
                assert_eq!(par.cost, ser.cost, "{reduction:?} cost");
                assert_eq!(par.down_cost, ser.down_cost, "{reduction:?} down");
                assert_eq!(par.divider, ser.divider, "{reduction:?} divider");
            }
        }
    }

    #[test]
    fn groups_sorted_by_uuid_and_parallel_coalesced() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        for s in 0..t.switches.len() {
            let gs: Vec<GroupRef<'_>> = prep.groups(s).collect();
            assert_eq!(gs.len(), prep.num_groups(s));
            for w in gs.windows(2) {
                assert!(
                    t.switches[w[0].remote as usize].uuid
                        < t.switches[w[1].remote as usize].uuid
                );
            }
            // In fig1 leaves have p2 = 2 parallel links per up neighbor.
            if t.switches[s].level == 0 {
                for g in &gs {
                    assert_eq!(g.ports.len(), 2);
                    assert!(g.up);
                }
            }
        }
    }

    #[test]
    fn build_into_reuses_buffers_consistently() {
        // Rebuilding into the same Prep across different topologies must
        // leave no stale state behind.
        let a = PgftParams::fig1().build();
        let b = PgftParams::small().build();
        let mut scratch = PrepScratch::default();
        let mut p = Prep::default();
        Prep::build_into(&b, &mut p, &mut scratch);
        Prep::build_into(&a, &mut p, &mut scratch);
        let fresh = Prep::new(&a);
        assert_eq!(p.leaves, fresh.leaves);
        assert_eq!(p.leaf_index, fresh.leaf_index);
        assert_eq!(p.group_offsets, fresh.group_offsets);
        assert_eq!(p.group_meta, fresh.group_meta);
        assert_eq!(p.port_offsets, fresh.port_offsets);
        assert_eq!(p.ports, fresh.ports);
        assert_eq!(p.up_groups, fresh.up_groups);
        assert_eq!(p.by_level_up, fresh.by_level_up);
        assert_eq!(p.level_offsets, fresh.level_offsets);
        assert_eq!(p.leaf_node_offsets, fresh.leaf_node_offsets);
        assert_eq!(p.leaf_nodes, fresh.leaf_nodes);
    }

    #[test]
    fn build_into_thread_invariant() {
        // The two-pass parallel CSR build must emit byte-identical tables
        // at every thread count (claim order never reaches the output).
        use crate::util::par::{set_threads, thread_override_lock};
        let _g = thread_override_lock();
        let t = PgftParams::small().build();
        set_threads(Some(1));
        let serial = Prep::new(&t);
        set_threads(Some(8));
        let par = Prep::new(&t);
        set_threads(None);
        assert_eq!(par.group_offsets, serial.group_offsets);
        assert_eq!(par.group_meta, serial.group_meta);
        assert_eq!(par.port_offsets, serial.port_offsets);
        assert_eq!(par.ports, serial.ports);
        assert_eq!(par.up_groups, serial.up_groups);
    }

    #[test]
    fn group_meta_accessors_decode() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        for s in 0..t.switches.len() {
            for (gi, g) in prep.groups(s).enumerate() {
                let flat = prep.group_offsets[s] as usize + gi;
                assert_eq!(prep.group_remote(flat), g.remote);
                assert_eq!(prep.group_is_up(flat), g.up);
            }
        }
    }

    #[test]
    fn level_spans_partition_switches() {
        let t = PgftParams::small().build();
        let prep = Prep::new(&t);
        let mut seen = vec![false; t.switches.len()];
        for lvl in 0..prep.num_levels() {
            for &s in prep.level_span(lvl) {
                assert_eq!(t.switches[s as usize].level as usize, lvl);
                assert!(!seen[s as usize]);
                seen[s as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn leaf_nodes_csr_matches_topology() {
        let t = PgftParams::small().build();
        let prep = Prep::new(&t);
        for (li, &l) in prep.leaves.iter().enumerate() {
            assert_eq!(prep.nodes_of_leaf_idx(li as u32), &t.nodes_of_leaf(l)[..]);
        }
    }

    #[test]
    fn derive_ranks_matches_constructed() {
        let t = PgftParams::small().build();
        let ranks = derive_ranks(&t);
        for (s, sw) in t.switches.iter().enumerate() {
            assert_eq!(ranks[s], sw.level, "switch {s}");
        }
    }

    #[test]
    fn bfs_dist_sane() {
        let t = PgftParams::fig1().build();
        let l0 = t.leaf_switches()[0];
        let d = bfs_dist(&t, l0);
        assert_eq!(d[l0 as usize], 0);
        // Everything reachable within 4 hops in fig1.
        assert!(d.iter().all(|&x| x <= 4));
    }
}
