//! Shared routing preprocessing: ranks, port groups, and the cost/divider
//! sweeps of the paper's Algorithm 1.

use crate::topology::{NodeId, PortTarget, SwitchId, Topology};

/// Unreachable cost sentinel.
pub const INF: u16 = u16::MAX;

/// A port group: all ports of a switch linked to the same remote switch
/// (the paper prepares these sorted by remote UUID "to help with
/// same-destination route coalescing").
#[derive(Clone, Debug)]
pub struct Group {
    pub remote: SwitchId,
    /// Local port indices, ascending.
    pub ports: Vec<u16>,
    /// True if `remote` is at a higher level (uplink group).
    pub up: bool,
}

/// Preprocessed view of a topology shared by the routing engines.
pub struct Prep {
    /// Leaf switches, ascending id.
    pub leaves: Vec<SwitchId>,
    /// switch id -> index into `leaves` (or `u32::MAX`).
    pub leaf_index: Vec<u32>,
    /// Per switch: port groups sorted by remote switch UUID.
    pub groups: Vec<Vec<Group>>,
    /// Per switch: number of uplink groups (`#{s' ⊃ s}` in the paper).
    pub up_groups: Vec<u32>,
    /// Switch ids sorted by ascending level (stable by id).
    pub by_level_up: Vec<SwitchId>,
}

impl Prep {
    pub fn new(topo: &Topology) -> Self {
        let ns = topo.switches.len();
        let leaves = topo.leaf_switches();
        let mut leaf_index = vec![u32::MAX; ns];
        for (i, &l) in leaves.iter().enumerate() {
            leaf_index[l as usize] = i as u32;
        }
        let mut groups: Vec<Vec<Group>> = Vec::with_capacity(ns);
        for (s, sw) in topo.switches.iter().enumerate() {
            let mut gs: Vec<Group> = Vec::new();
            for (pi, p) in sw.ports.iter().enumerate() {
                if let PortTarget::Switch { sw: r, .. } = *p {
                    match gs.iter_mut().find(|g| g.remote == r) {
                        Some(g) => g.ports.push(pi as u16),
                        None => gs.push(Group {
                            remote: r,
                            ports: vec![pi as u16],
                            up: topo.switches[r as usize].level
                                > topo.switches[s].level,
                        }),
                    }
                }
            }
            gs.sort_by_key(|g| topo.switches[g.remote as usize].uuid);
            groups.push(gs);
        }
        let up_groups = groups
            .iter()
            .map(|gs| gs.iter().filter(|g| g.up).count() as u32)
            .collect();
        let mut by_level_up: Vec<SwitchId> = (0..ns as SwitchId).collect();
        by_level_up.sort_by_key(|&s| (topo.switches[s as usize].level, s));
        Self {
            leaves,
            leaf_index,
            groups,
            up_groups,
            by_level_up,
        }
    }
}

/// Divider reduction choice of Algorithm 1 (the paper uses `Max`; the
/// `FirstPath` variant is the alternative it reports as showing "little to
/// no change" — kept for the ablation bench).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DividerReduction {
    Max,
    FirstPath,
}

/// Output of the paper's Algorithm 1 plus the pure-down costs needed by
/// UPDN-style engines.
pub struct Costs {
    /// `c[s * num_leaves + li]`: min hops from switch `s` to leaf
    /// `leaves[li]` under up*/down* restriction.
    pub cost: Vec<u16>,
    /// Same layout, but down-moves only (the state after the upward sweep).
    pub down_cost: Vec<u16>,
    /// Divider Π per switch.
    pub divider: Vec<u64>,
    pub num_leaves: usize,
}

impl Costs {
    #[inline]
    pub fn cost(&self, s: SwitchId, leaf_idx: u32) -> u16 {
        self.cost[s as usize * self.num_leaves + leaf_idx as usize]
    }

    #[inline]
    pub fn down(&self, s: SwitchId, leaf_idx: u32) -> u16 {
        self.down_cost[s as usize * self.num_leaves + leaf_idx as usize]
    }
}

/// Algorithm 1: compute costs and dividers.
///
/// Upward sweep (switches in ascending level): relax each switch's
/// up-neighbors with `c+1` (yielding pure-down costs) and propagate
/// dividers `π = Π_s · #upgroups(s)` with the chosen reduction. Downward
/// sweep (descending level): relax down-neighbors with `c+1`, adding
/// up*/down* paths.
pub fn costs(topo: &Topology, prep: &Prep, reduction: DividerReduction) -> Costs {
    let ns = topo.switches.len();
    let nl = prep.leaves.len();
    let mut cost = vec![INF; ns * nl];
    let mut divider = vec![1u64; ns];
    let mut divider_set = vec![false; ns];
    for (li, &l) in prep.leaves.iter().enumerate() {
        cost[l as usize * nl + li] = 0;
    }
    // Upward sweep.
    for &s in &prep.by_level_up {
        let su = s as usize;
        let pi = divider[su] * prep.up_groups[su].max(1) as u64;
        for g in &prep.groups[su] {
            if !g.up {
                continue;
            }
            let r = g.remote as usize;
            // Cost relaxation toward the up-neighbor.
            for li in 0..nl {
                let via = cost[su * nl + li].saturating_add(1);
                if via < cost[r * nl + li] {
                    cost[r * nl + li] = via;
                }
            }
            // Divider reduction.
            match reduction {
                DividerReduction::Max => {
                    if pi > divider[r] {
                        divider[r] = pi;
                    }
                }
                DividerReduction::FirstPath => {
                    if !divider_set[r] {
                        divider[r] = pi;
                        divider_set[r] = true;
                    }
                }
            }
        }
    }
    let down_cost = cost.clone();
    // Downward sweep.
    for &s in prep.by_level_up.iter().rev() {
        let su = s as usize;
        for g in &prep.groups[su] {
            if g.up {
                continue;
            }
            let r = g.remote as usize;
            for li in 0..nl {
                let via = cost[su * nl + li].saturating_add(1);
                if via < cost[r * nl + li] {
                    cost[r * nl + li] = via;
                }
            }
        }
    }
    Costs {
        cost,
        down_cost,
        divider,
        num_leaves: nl,
    }
}

/// Plain BFS hop distances from `from` to every switch (undirected,
/// ignoring levels) — the MinHop metric.
pub fn bfs_dist(topo: &Topology, from: SwitchId) -> Vec<u16> {
    let ns = topo.switches.len();
    let mut dist = vec![INF; ns];
    let mut queue = std::collections::VecDeque::new();
    dist[from as usize] = 0;
    queue.push_back(from);
    while let Some(s) = queue.pop_front() {
        let d = dist[s as usize];
        for p in &topo.switches[s as usize].ports {
            if let PortTarget::Switch { sw: r, .. } = *p {
                if dist[r as usize] == INF {
                    dist[r as usize] = d + 1;
                    queue.push_back(r);
                }
            }
        }
    }
    dist
}

/// Derive ranks (levels) from scratch, as the paper's preprocessing does:
/// leaf switches (those with attached nodes) are level 0 and every other
/// switch gets its undirected BFS distance to the nearest leaf. On intact
/// and moderately-degraded PGFTs this equals the constructed level; it is
/// used by tests and by generic (non-PGFT) inputs.
pub fn derive_ranks(topo: &Topology) -> Vec<u8> {
    let ns = topo.switches.len();
    let mut rank = vec![u8::MAX; ns];
    let mut queue = std::collections::VecDeque::new();
    for (s, sw) in topo.switches.iter().enumerate() {
        if sw.ports.iter().any(|p| matches!(p, PortTarget::Node { .. })) {
            rank[s] = 0;
            queue.push_back(s as SwitchId);
        }
    }
    while let Some(s) = queue.pop_front() {
        let r = rank[s as usize];
        for p in &topo.switches[s as usize].ports {
            if let PortTarget::Switch { sw: n, .. } = *p {
                if rank[n as usize] == u8::MAX {
                    rank[n as usize] = r + 1;
                    queue.push_back(n);
                }
            }
        }
    }
    rank
}

/// The destination's leaf switch λ_d.
#[inline]
pub fn leaf_of(topo: &Topology, d: NodeId) -> SwitchId {
    topo.nodes[d as usize].leaf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::pgft::PgftParams;

    #[test]
    fn fig1_costs_structure() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        // Leaf to itself: 0; leaf to any other leaf: 2 (shared mid) or 4.
        for (li, &l) in prep.leaves.iter().enumerate() {
            for (lj, &l2) in prep.leaves.iter().enumerate() {
                let v = c.cost(l, lj as u32);
                if li == lj {
                    assert_eq!(v, 0);
                } else {
                    assert!(v == 2 || v == 4, "leaf {l}->{l2} cost {v}");
                }
            }
        }
    }

    #[test]
    fn fig1_dividers() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        for (s, sw) in t.switches.iter().enumerate() {
            let expect = match sw.level {
                0 => 1,
                1 => 2, // leaf up-groups = w2 = 2
                2 => 4, // 2 * w3 = 2*2
                _ => unreachable!(),
            };
            assert_eq!(c.divider[s], expect, "switch {s} level {}", sw.level);
        }
    }

    #[test]
    fn down_cost_is_infinite_upward() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        // From a leaf, pure-down cost to a different leaf is INF.
        let l0 = prep.leaves[0];
        assert_eq!(c.down(l0, 1), INF);
        assert_eq!(c.down(l0, 0), 0);
    }

    #[test]
    fn cost_upper_bounds_down_cost() {
        let t = PgftParams::small().build();
        let prep = Prep::new(&t);
        let c = costs(&t, &prep, DividerReduction::Max);
        for s in 0..t.switches.len() {
            for li in 0..prep.leaves.len() {
                assert!(c.cost[s * prep.leaves.len() + li] <= c.down_cost[s * prep.leaves.len() + li]);
            }
        }
    }

    #[test]
    fn groups_sorted_by_uuid_and_parallel_coalesced() {
        let t = PgftParams::fig1().build();
        let prep = Prep::new(&t);
        for (s, gs) in prep.groups.iter().enumerate() {
            for w in gs.windows(2) {
                assert!(
                    t.switches[w[0].remote as usize].uuid
                        < t.switches[w[1].remote as usize].uuid
                );
            }
            // In fig1 leaves have p2 = 2 parallel links per up neighbor.
            if t.switches[s].level == 0 {
                for g in gs {
                    assert_eq!(g.ports.len(), 2);
                    assert!(g.up);
                }
            }
        }
    }

    #[test]
    fn derive_ranks_matches_constructed() {
        let t = PgftParams::small().build();
        let ranks = derive_ranks(&t);
        for (s, sw) in t.switches.iter().enumerate() {
            assert_eq!(ranks[s], sw.level, "switch {s}");
        }
    }

    #[test]
    fn bfs_dist_sane() {
        let t = PgftParams::fig1().build();
        let l0 = t.leaf_switches()[0];
        let d = bfs_dist(&t, l0);
        assert_eq!(d[l0 as usize], 0);
        // Everything reachable within 4 hops in fig1.
        assert!(d.iter().all(|&x| x <= 4));
    }
}
