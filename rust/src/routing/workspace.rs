//! Persistent reroute workspace: the Dmodc pipeline into reused buffers.
//!
//! The paper's headline runtime claim — complete rerouting of tens of
//! thousands of nodes "in less than a second" — assumes the fabric
//! manager's reaction path does no cold-start work per event. The original
//! `FabricManager::reroute` rebuilt everything from freshly allocated
//! memory on every fault; this workspace owns every intermediate product
//! of the pipeline (degraded-topology scratch, CSR `Prep`, cost/divider
//! buffers, the NID array) and refills the caller's topology and LFT
//! buffers in place, so that steady-state fault-storm rerouting performs
//! **zero heap allocation** in the routing pipeline
//! (asserted by `tests/equivalence.rs` with a counting allocator; see
//! EXPERIMENTS.md §Perf).
//!
//! Produced LFTs are bit-identical to [`dmodc::route_reference`] — the
//! equivalence suite checks intact and degraded topologies, every
//! thread count, and repeated reuse (event → recovery → event).
//!
//! [`dmodc::Engine`] wraps this workspace behind the
//! [`RoutingEngine`](super::RoutingEngine) trait; the baseline engines
//! own analogous per-algorithm workspaces (see `routing/engine.rs`).

use super::common::{self, Costs, Prep, PrepScratch};
use super::dmodc::{self, NidOrder, NidScratch, Options};
use super::{validity, Lft};
use crate::topology::degrade::{self, DegradeScratch};
use crate::topology::{NodeId, SwitchId, Topology};
use std::collections::HashSet;

/// Reusable state for repeated full reroutes (owned by `FabricManager`).
pub struct RerouteWorkspace {
    pub opts: Options,
    /// Preprocessing of the *last rerouted* topology.
    pub prep: Prep,
    /// Algorithm-1 products for the last rerouted topology.
    pub costs: Costs,
    /// Algorithm-2 NIDs for the last rerouted topology.
    pub nids: Vec<u64>,
    prep_scratch: PrepScratch,
    nid_scratch: NidScratch,
    degrade_scratch: DegradeScratch,
}

impl RerouteWorkspace {
    pub fn new(opts: Options) -> Self {
        Self {
            opts,
            prep: Prep::default(),
            costs: Costs::default(),
            nids: Vec::new(),
            prep_scratch: PrepScratch::default(),
            nid_scratch: NidScratch::default(),
            degrade_scratch: DegradeScratch::default(),
        }
    }

    /// Rebuild the degraded topology in place (`degrade::apply_into`
    /// semantics — bit-identical to `degrade::apply`), reusing the
    /// workspace's degradation scratch.
    pub fn materialize(
        &mut self,
        reference: &Topology,
        dead_switches: &HashSet<SwitchId>,
        dead_cables: &HashSet<(SwitchId, u16)>,
        out: &mut Topology,
    ) {
        degrade::apply_into(
            reference,
            dead_switches,
            dead_cables,
            out,
            &mut self.degrade_scratch,
        );
    }

    /// Run the full Dmodc pipeline for `topo` into `out`, reusing every
    /// buffer. After this call `prep`/`costs`/`nids` describe `topo`
    /// (used by [`RerouteWorkspace::validate`] and
    /// [`RerouteWorkspace::alternatives_into`]).
    pub fn reroute_into(&mut self, topo: &Topology, out: &mut Lft) {
        Prep::build_into(topo, &mut self.prep, &mut self.prep_scratch);
        common::costs_into(topo, &self.prep, self.opts.reduction, &mut self.costs);
        match self.opts.nid_order {
            NidOrder::Topological => dmodc::topological_nids_into(
                topo,
                &self.prep,
                &self.costs,
                &mut self.nids,
                &mut self.nid_scratch,
            ),
            NidOrder::UuidFlat => dmodc::uuid_flat_nids_into(
                topo,
                &self.prep,
                &mut self.nids,
                &mut self.nid_scratch,
            ),
        }
        out.reset(topo.switches.len(), topo.nodes.len());
        dmodc::fill_rows(topo, &self.prep, &self.costs, &self.nids, out);
    }

    /// The paper's validity pass for `topo`/`lft`, reusing the costs
    /// already computed by the last [`RerouteWorkspace::reroute_into`]
    /// instead of rebuilding `Prep` + Algorithm 1 from scratch (which
    /// roughly doubled the reaction latency when validation was on).
    pub fn validate(&self, topo: &Topology, lft: &Lft) -> Result<(), String> {
        validity::check_with(topo, lft, &self.prep, &self.costs)
    }

    /// Equation-(2) alternative ports against the last rerouted topology,
    /// into a caller buffer (the fast-mitigation path).
    pub fn alternatives_into(
        &self,
        topo: &Topology,
        s: u32,
        d: NodeId,
        out: &mut Vec<u16>,
    ) {
        dmodc::alternatives_into(topo, &self.prep, &self.costs, s, d, out);
    }
}

impl Default for RerouteWorkspace {
    fn default() -> Self {
        Self::new(Options::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::dmodc::route_reference;
    use crate::topology::pgft::PgftParams;
    use crate::util::rng::Rng;

    #[test]
    fn workspace_reroute_matches_reference_across_reuse() {
        let t = PgftParams::small().build();
        let mut rng = Rng::new(5);
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::new(0, 0);
        // Alternate intact / degraded to exercise buffer shrink + regrow.
        for round in 0..4 {
            let topo = if round % 2 == 0 {
                t.clone()
            } else {
                crate::topology::degrade::remove_random_links(&t, &mut rng, 3 + round)
            };
            ws.reroute_into(&topo, &mut out);
            let reference = route_reference(&topo, &Options::default());
            assert_eq!(out.raw(), reference.raw(), "round {round}");
            assert!(ws.validate(&topo, &out).is_ok(), "round {round}");
        }
    }

    #[test]
    fn materialize_matches_apply() {
        use std::collections::HashSet;
        let t = PgftParams::small().build();
        let dead_sw: HashSet<u32> = [t.leaf_switches().len() as u32 + 1].into_iter().collect();
        let mut dead_cb = HashSet::new();
        dead_cb.insert(crate::topology::degrade::cables(&t)[4]);
        let mut ws = RerouteWorkspace::default();
        let mut got = Topology::default();
        ws.materialize(&t, &dead_sw, &dead_cb, &mut got);
        let want = crate::topology::degrade::apply(&t, &dead_sw, &dead_cb);
        assert_eq!(got.nodes.len(), want.nodes.len());
        assert_eq!(got.switches.len(), want.switches.len());
        assert_eq!(got.num_levels, want.num_levels);
        assert_eq!(got.port_offsets, want.port_offsets);
        for (a, b) in got.switches.iter().zip(&want.switches) {
            assert_eq!(a.uuid, b.uuid);
            assert_eq!(a.level, b.level);
            assert_eq!(a.ports, b.ports);
        }
        for (a, b) in got.nodes.iter().zip(&want.nodes) {
            assert_eq!(a.uuid, b.uuid);
            assert_eq!(a.leaf, b.leaf);
            assert_eq!(a.leaf_port, b.leaf_port);
        }
    }
}
