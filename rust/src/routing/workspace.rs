//! Persistent reroute workspace: the Dmodc pipeline into reused buffers.
//!
//! The paper's headline runtime claim — complete rerouting of tens of
//! thousands of nodes "in less than a second" — assumes the fabric
//! manager's reaction path does no cold-start work per event. The original
//! `FabricManager::reroute` rebuilt everything from freshly allocated
//! memory on every fault; this workspace owns every intermediate product
//! of the pipeline (degraded-topology scratch, CSR `Prep`, cost/divider
//! buffers, the NID array) and refills the caller's topology and LFT
//! buffers in place, so that steady-state fault-storm rerouting performs
//! **zero heap allocation** in the routing pipeline
//! (asserted by `tests/equivalence.rs` with a counting allocator; see
//! EXPERIMENTS.md §Perf).
//!
//! Produced LFTs are bit-identical to [`dmodc::route_reference`] — the
//! equivalence suite checks intact and degraded topologies, every
//! thread count, and repeated reuse (event → recovery → event).
//!
//! On top of the full path, [`RerouteWorkspace::reroute_delta_into`]
//! offers the *incremental* path (EXPERIMENTS.md §"Incremental
//! reroute"): the cheap pipeline products are rebuilt and diffed
//! against the previous event's, and only the LFT rows whose inputs
//! changed are refilled — falling back to a full row fill whenever the
//! dirty set cannot be bounded (see [`delta`](super::delta)). The delta
//! path is bit-identical to a full reroute after every event
//! (`tests/delta_diff.rs`).
//!
//! On top of the sequential delta path, [`RerouteWorkspace::snapshot`] /
//! [`RerouteWorkspace::restore_from`] support *baseline forking* (see
//! `routing::snapshot`): a frozen snapshot of one reroute re-arms any
//! workspace so the next delta call diffs against that shared baseline
//! instead of the previous sample — the degradation-campaign hot path,
//! where every throw is an independent fork of the intact fabric.
//!
//! [`dmodc::Engine`] wraps this workspace behind the
//! [`RoutingEngine`](super::RoutingEngine) trait; the baseline engines
//! own analogous per-algorithm workspaces (see `routing/engine.rs`).

use super::common::{self, Costs, Prep, PrepScratch};
use super::delta::{self, DeltaConfig, DeltaOutcome, DeltaStats, FallbackReason};
use super::dmodc::{self, NidOrder, NidScratch, Options};
use super::snapshot::Snapshot;
use super::{validity, Lft};
use crate::topology::degrade::{self, DegradeScratch};
use crate::topology::{NodeId, SwitchId, Topology};
use crate::util::{alloc_guard, time};
use std::collections::HashSet;

/// Per-stage wall times of the most recent reroute (seconds). Makes the
/// paper-scale profile observable instead of guessed: the routing
/// workspace fills `prep`/`costs`/`nids`/`fill` during
/// [`RerouteWorkspace::reroute_into`] / `reroute_delta_into`, and the
/// fabric manager adds `commit` around its table upload
/// (`ManagerReport::timings`). Stages not run by an event (e.g. `fill`
/// on a clean delta, `commit` outside a manager) stay 0.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RerouteTimings {
    /// CSR preprocessing ([`Prep::build_into`]).
    pub prep_s: f64,
    /// Algorithm 1 cost/divider sweeps.
    pub costs_s: f64,
    /// Algorithm 2 NID assignment.
    pub nids_s: f64,
    /// LFT row fill (full or partial).
    pub fill_s: f64,
    /// Table upload/commit (filled by the fabric manager, not here).
    pub commit_s: f64,
}

impl RerouteTimings {
    /// Sum of all recorded stages.
    pub fn total_s(&self) -> f64 {
        self.prep_s + self.costs_s + self.nids_s + self.fill_s + self.commit_s
    }
}

/// Reusable state for repeated full reroutes (owned by `FabricManager`).
pub struct RerouteWorkspace {
    pub opts: Options,
    /// Knobs for the incremental path.
    pub delta: DeltaConfig,
    /// Preprocessing of the *last rerouted* topology.
    pub prep: Prep,
    /// Algorithm-1 products for the last rerouted topology.
    pub costs: Costs,
    /// Algorithm-2 NIDs for the last rerouted topology.
    pub nids: Vec<u64>,
    prep_scratch: PrepScratch,
    nid_scratch: NidScratch,
    degrade_scratch: DegradeScratch,
    /// Products of the previous reroute (delta-path diff baseline).
    prev: delta::PrevProducts,
    /// Dirty-set scratch for the delta path.
    dirty: delta::DirtySet,
    /// A reroute has completed, so `prep`/`costs`/`nids` describe the
    /// topology of the caller's current tables.
    routed: bool,
    /// `prev` was restored from a [`Snapshot`] whose tables have this
    /// `(switches, nodes)` shape; the next `reroute_delta_into` must
    /// diff against it instead of re-capturing from the workspace
    /// products. Consumed (and checked against the caller's buffer) by
    /// the next delta call; cleared by any full reroute.
    armed: Option<(usize, usize)>,
    /// Per-stage wall times of the most recent reroute.
    timings: RerouteTimings,
}

impl RerouteWorkspace {
    pub fn new(opts: Options) -> Self {
        Self {
            opts,
            delta: DeltaConfig::default(),
            prep: Prep::default(),
            costs: Costs::default(),
            nids: Vec::new(),
            prep_scratch: PrepScratch::default(),
            nid_scratch: NidScratch::default(),
            degrade_scratch: DegradeScratch::default(),
            prev: delta::PrevProducts::default(),
            dirty: delta::DirtySet::default(),
            routed: false,
            armed: None,
            timings: RerouteTimings::default(),
        }
    }

    /// Per-stage wall times of the most recent reroute (`commit_s` is
    /// always 0 here — the fabric manager owns the upload stage).
    pub fn timings(&self) -> RerouteTimings {
        self.timings
    }

    /// Discard all cross-call history, as if no reroute had ever run.
    ///
    /// The panic-containment path calls this after `catch_unwind`
    /// traps a reroute mid-pipeline: `prep`/`costs`/`nids` may then
    /// describe a half-built state, and `routed`/`armed`/`prev` would
    /// let the next delta call diff against that poison. Dropping the
    /// history forces the next call onto the full path
    /// (`FallbackReason::NoHistory`), which rebuilds every product from
    /// the topology alone. Buffers keep their capacity — reinit costs
    /// no allocation and no correctness.
    pub fn reinit(&mut self) {
        self.routed = false;
        self.armed = None;
        self.prev.invalidate();
    }

    /// Rebuild the degraded topology in place (`degrade::apply_into`
    /// semantics — bit-identical to `degrade::apply`), reusing the
    /// workspace's degradation scratch.
    pub fn materialize(
        &mut self,
        reference: &Topology,
        dead_switches: &HashSet<SwitchId>,
        dead_cables: &HashSet<(SwitchId, u16)>,
        out: &mut Topology,
    ) {
        degrade::apply_into(
            reference,
            dead_switches,
            dead_cables,
            out,
            &mut self.degrade_scratch,
        );
    }

    /// Rebuild `prep`/`costs`/`nids` for `topo` into the reused buffers
    /// (the cheap pipeline stages, shared by the full and delta paths).
    fn rebuild_products(&mut self, topo: &Topology) {
        self.timings = RerouteTimings::default();
        let t0 = time::now();
        Prep::build_into(topo, &mut self.prep, &mut self.prep_scratch);
        self.timings.prep_s = t0.elapsed().as_secs_f64();
        let t0 = time::now();
        common::costs_into(topo, &self.prep, self.opts.reduction, &mut self.costs);
        self.timings.costs_s = t0.elapsed().as_secs_f64();
        let t0 = time::now();
        match self.opts.nid_order {
            NidOrder::Topological => dmodc::topological_nids_into(
                topo,
                &self.prep,
                &self.costs,
                &mut self.nids,
                &mut self.nid_scratch,
            ),
            NidOrder::UuidFlat => dmodc::uuid_flat_nids_into(
                topo,
                &self.prep,
                &mut self.nids,
                &mut self.nid_scratch,
            ),
        }
        self.timings.nids_s = t0.elapsed().as_secs_f64();
    }

    /// Run the full Dmodc pipeline for `topo` into `out`, reusing every
    /// buffer. After this call `prep`/`costs`/`nids` describe `topo`
    /// (used by [`RerouteWorkspace::validate`] and
    /// [`RerouteWorkspace::alternatives_into`]).
    pub fn reroute_into(&mut self, topo: &Topology, out: &mut Lft) {
        let _guard = alloc_guard::region("reroute-full");
        self.rebuild_products(topo);
        let t0 = time::now();
        out.reset(topo.switches.len(), topo.nodes.len());
        dmodc::fill_rows(topo, &self.prep, &self.costs, &self.nids, out);
        self.timings.fill_s = t0.elapsed().as_secs_f64();
        self.routed = true;
        self.armed = None;
    }

    /// Freeze the products of the most recent reroute together with the
    /// tables it produced as a shared, immutable [`Snapshot`] (see
    /// `routing::snapshot`). `lft` must be this workspace's most recent
    /// output (any entry point) — asserted by shape.
    pub fn snapshot(&self, lft: &Lft) -> Snapshot {
        assert!(self.routed, "snapshot requires a completed reroute");
        assert!(
            lft.num_switches() + 1 == self.prep.group_offsets.len()
                && lft.num_nodes() == self.prep.leaf_nodes.len(),
            "snapshot LFT must be this workspace's most recent output"
        );
        let mut products = delta::PrevProducts::default();
        products.capture(&self.prep, &self.costs, &self.nids);
        Snapshot::from_parts(products, lft.clone())
    }

    /// Re-arm this workspace so the **next** [`reroute_delta_into`]
    /// diffs against `snap`'s baseline instead of this workspace's
    /// previous reroute, *and* rewind `out` to the baseline tables the
    /// delta fill will patch — the campaign fork path (degrade →
    /// restore → delta) in one unviolatable step. Pass the same buffer
    /// to the next delta call; a different-shaped buffer there degrades
    /// to a full fill (`FallbackReason::NoHistory`) rather than
    /// trusting a broken contract.
    ///
    /// The restore copies the shared buffers into this workspace's
    /// reused scratch (`Vec::clone_from`) — zero heap allocation once
    /// capacities have converged, `Arc` contents never mutated.
    ///
    /// [`reroute_delta_into`]: RerouteWorkspace::reroute_delta_into
    pub fn restore_from(&mut self, snap: &Snapshot, out: &mut Lft) {
        snap.restore_lft_into(out);
        self.prev.assign_from(snap.products());
        self.armed = Some((snap.num_switches(), snap.num_nodes()));
    }

    /// Incremental reroute: refill only the LFT rows the transition from
    /// the previously rerouted topology to `topo` can change, falling
    /// back to a full row fill when the dirty set cannot be bounded
    /// (see [`delta`](super::delta) for the rules). The result is
    /// **bit-identical** to [`RerouteWorkspace::reroute_into`] either
    /// way (`tests/delta_diff.rs` fuzzes this across random event
    /// sequences and thread counts).
    ///
    /// Contract: `out` must hold the tables produced by this
    /// workspace's most recent reroute (any entry point) — the delta
    /// path preserves its clean rows. A shape mismatch is detected and
    /// degrades to the full fill; content tampering (e.g. a fabric
    /// manager's `fast_patch`) is not detectable here, so such callers
    /// must request a full reroute instead.
    ///
    /// On the delta path, `touched` receives the refilled row indices
    /// (ascending) for partial upload accounting; on the full path it
    /// receives every row. The buffer is reused — no steady-state
    /// allocation.
    pub fn reroute_delta_into(
        &mut self,
        topo: &Topology,
        out: &mut Lft,
        touched: &mut Vec<u32>,
    ) -> DeltaOutcome {
        let _guard = alloc_guard::region("reroute-delta");
        touched.clear();
        match self.armed.take() {
            // Restored from a snapshot: `prev` already holds the
            // baseline `out` was rewound to — do not recapture it.
            Some((ns, nn)) if out.num_switches() == ns && out.num_nodes() == nn => {}
            // Armed, but the caller's buffer does not match the
            // baseline shape: the restore contract was violated, so the
            // history is unusable (full fill below).
            Some(_) => self.prev.invalidate(),
            // Sequential path: capture the previous products before the
            // rebuild overwrites them — they describe the topology
            // `out` was routed for.
            None => {
                if self.routed
                    && out.num_switches() + 1 == self.prep.group_offsets.len()
                    && out.num_nodes() == self.prep.leaf_nodes.len()
                {
                    self.prev.capture(&self.prep, &self.costs, &self.nids);
                } else {
                    self.prev.invalidate();
                }
            }
        }
        self.rebuild_products(topo);

        let mut reason = delta::eligibility(&self.prev, &self.prep, &self.costs, &self.nids);
        let mut stats = DeltaStats::default();
        if reason.is_none() {
            stats = self.dirty.compute(&self.prev, &self.prep, &self.costs);
            let rows_touched = stats.rows_full + stats.rows_partial;
            if rows_touched as f64 > self.delta.max_dirty_row_frac * topo.switches.len() as f64
            {
                reason = Some(FallbackReason::Threshold);
            }
        }
        let t0 = time::now();
        let outcome = match reason {
            Some(r) => {
                out.reset(topo.switches.len(), topo.nodes.len());
                dmodc::fill_rows(topo, &self.prep, &self.costs, &self.nids, out);
                touched.extend(0..topo.switches.len() as u32);
                DeltaOutcome::Full(r)
            }
            None => {
                dmodc::fill_rows_partial(
                    topo,
                    &self.prep,
                    &self.costs,
                    &self.nids,
                    &self.dirty,
                    out,
                );
                touched.extend(self.dirty.touched_rows());
                DeltaOutcome::Delta(stats)
            }
        };
        self.timings.fill_s = t0.elapsed().as_secs_f64();
        self.routed = true;
        outcome
    }

    /// The paper's validity pass for `topo`/`lft`, reusing the costs
    /// already computed by the last [`RerouteWorkspace::reroute_into`]
    /// instead of rebuilding `Prep` + Algorithm 1 from scratch (which
    /// roughly doubled the reaction latency when validation was on).
    pub fn validate(&self, topo: &Topology, lft: &Lft) -> Result<(), String> {
        validity::check_with(topo, lft, &self.prep, &self.costs)
    }

    /// Equation-(2) alternative ports against the last rerouted topology,
    /// into a caller buffer (the fast-mitigation path).
    pub fn alternatives_into(
        &self,
        topo: &Topology,
        s: u32,
        d: NodeId,
        out: &mut Vec<u16>,
    ) {
        dmodc::alternatives_into(topo, &self.prep, &self.costs, s, d, out);
    }
}

impl Default for RerouteWorkspace {
    fn default() -> Self {
        Self::new(Options::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::dmodc::route_reference;
    use crate::topology::pgft::PgftParams;
    use crate::util::rng::Rng;

    #[test]
    fn workspace_reroute_matches_reference_across_reuse() {
        let t = PgftParams::small().build();
        let mut rng = Rng::new(5);
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::new(0, 0);
        // Alternate intact / degraded to exercise buffer shrink + regrow.
        for round in 0..4 {
            let topo = if round % 2 == 0 {
                t.clone()
            } else {
                crate::topology::degrade::remove_random_links(&t, &mut rng, 3 + round)
            };
            ws.reroute_into(&topo, &mut out);
            let reference = route_reference(&topo, &Options::default());
            assert_eq!(out.raw(), reference.raw(), "round {round}");
            assert!(ws.validate(&topo, &out).is_ok(), "round {round}");
        }
    }

    #[test]
    fn delta_reroute_first_call_is_full_and_correct() {
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::default();
        let mut touched = Vec::new();
        let outcome = ws.reroute_delta_into(&t, &mut out, &mut touched);
        assert_eq!(outcome, DeltaOutcome::Full(FallbackReason::NoHistory));
        assert_eq!(touched.len(), t.switches.len());
        let want = route_reference(&t, &Options::default());
        assert_eq!(out.raw(), want.raw());
    }

    #[test]
    fn delta_reroute_parallel_cable_touches_two_rows() {
        use crate::topology::degrade;
        use std::collections::HashSet;
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::default();
        let mut touched = Vec::new();
        ws.reroute_delta_into(&t, &mut out, &mut touched);
        // Kill one cable of a parallel pair: group survives, so costs,
        // dividers and NIDs are untouched — only the endpoints refill.
        let dead: HashSet<(SwitchId, u16)> =
            [degrade::cables(&t)[0]].into_iter().collect();
        let d = degrade::apply(&t, &HashSet::new(), &dead);
        let outcome = ws.reroute_delta_into(&d, &mut out, &mut touched);
        match outcome {
            DeltaOutcome::Delta(st) => {
                assert_eq!(st.rows_full, 2);
                assert_eq!(st.rows_partial, 0);
                assert_eq!(st.rows_clean, t.switches.len() - 2);
            }
            other => panic!("expected delta tier, got {other:?}"),
        }
        assert_eq!(touched.len(), 2);
        let want = route_reference(&d, &Options::default());
        assert_eq!(out.raw(), want.raw());
        assert!(ws.validate(&d, &out).is_ok());
        // Recovery is delta-eligible too and restores the exact tables.
        let outcome = ws.reroute_delta_into(&t, &mut out, &mut touched);
        assert!(outcome.is_delta(), "recovery outcome {outcome:?}");
        let want = route_reference(&t, &Options::default());
        assert_eq!(out.raw(), want.raw());
    }

    #[test]
    fn delta_reroute_switch_fault_falls_back() {
        use crate::topology::degrade;
        use std::collections::HashSet;
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::default();
        let mut touched = Vec::new();
        ws.reroute_delta_into(&t, &mut out, &mut touched);
        let dead: HashSet<SwitchId> =
            [t.switches.len() as SwitchId - 1].into_iter().collect();
        let d = degrade::apply(&t, &dead, &HashSet::new());
        let outcome = ws.reroute_delta_into(&d, &mut out, &mut touched);
        assert_eq!(outcome, DeltaOutcome::Full(FallbackReason::ShapeChanged));
        assert_eq!(touched.len(), d.switches.len());
        let want = route_reference(&d, &Options::default());
        assert_eq!(out.raw(), want.raw());
    }

    #[test]
    fn forked_samples_from_one_snapshot_match_fresh_reroutes() {
        // The campaign loop: one baseline snapshot, many independent
        // degraded samples, each restore → delta. Every sample must be
        // bit-identical to a from-scratch reroute, regardless of what
        // the previous sample did to the workspace.
        let t = PgftParams::small().build();
        let cables = crate::topology::degrade::cables(&t);
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);
        let mut touched = Vec::new();
        let mut delta_samples = 0;
        for round in 0..6 {
            let dead: HashSet<(SwitchId, u16)> =
                [cables[round * 5 % cables.len()], cables[round * 11 % cables.len()]]
                    .into_iter()
                    .collect();
            let d = crate::topology::degrade::apply(&t, &HashSet::new(), &dead);
            ws.restore_from(&snap, &mut lft);
            let outcome = ws.reroute_delta_into(&d, &mut lft, &mut touched);
            if outcome.is_delta() {
                delta_samples += 1;
            }
            let want = route_reference(&d, &Options::default());
            assert_eq!(lft.raw(), want.raw(), "round {round} ({outcome:?})");
        }
        assert!(delta_samples > 0, "the fork path never took the delta tier");
    }

    #[test]
    fn armed_restore_with_mismatched_buffer_falls_back_correctly() {
        // Violating the restore contract (handing the delta call a
        // different buffer than the restored one) must not produce
        // wrong tables — it degrades to NoHistory + full fill.
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);
        ws.restore_from(&snap, &mut lft);
        let mut wrong = Lft::new(1, 1); // not the restored buffer
        let mut touched = Vec::new();
        let outcome = ws.reroute_delta_into(&t, &mut wrong, &mut touched);
        assert_eq!(outcome, DeltaOutcome::Full(FallbackReason::NoHistory));
        let want = route_reference(&t, &Options::default());
        assert_eq!(wrong.raw(), want.raw());
    }

    #[test]
    fn full_reroute_disarms_a_pending_restore() {
        // restore_from … reroute_into … reroute_delta_into must diff
        // against the *reroute_into* output, not the stale snapshot.
        let t = PgftParams::fig1().build();
        let cable = crate::topology::degrade::cables(&t)[0];
        let dead: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
        let d = crate::topology::degrade::apply(&t, &HashSet::new(), &dead);
        let mut ws = RerouteWorkspace::default();
        let mut lft = Lft::default();
        ws.reroute_into(&t, &mut lft);
        let snap = ws.snapshot(&lft);
        ws.restore_from(&snap, &mut lft);
        // A full reroute of the *degraded* topology intervenes.
        ws.reroute_into(&d, &mut lft);
        // The next delta (back to intact) must be correct — its baseline
        // is the degraded reroute, not the snapshot.
        let mut touched = Vec::new();
        let outcome = ws.reroute_delta_into(&t, &mut lft, &mut touched);
        let want = route_reference(&t, &Options::default());
        assert_eq!(lft.raw(), want.raw(), "{outcome:?}");
    }

    #[test]
    fn timings_populated_by_both_paths() {
        let t = PgftParams::fig1().build();
        let mut ws = RerouteWorkspace::default();
        assert_eq!(ws.timings(), RerouteTimings::default());
        let mut out = Lft::default();
        ws.reroute_into(&t, &mut out);
        let full = ws.timings();
        assert!(full.prep_s > 0.0 && full.costs_s > 0.0 && full.fill_s > 0.0);
        assert_eq!(full.commit_s, 0.0);
        assert!(full.total_s() >= full.prep_s + full.fill_s);
        let mut touched = Vec::new();
        ws.reroute_delta_into(&t, &mut out, &mut touched);
        let delta = ws.timings();
        assert!(delta.prep_s > 0.0 && delta.costs_s > 0.0);
    }

    #[test]
    fn materialize_matches_apply() {
        use std::collections::HashSet;
        let t = PgftParams::small().build();
        let dead_sw: HashSet<u32> = [t.leaf_switches().len() as u32 + 1].into_iter().collect();
        let mut dead_cb = HashSet::new();
        dead_cb.insert(crate::topology::degrade::cables(&t)[4]);
        let mut ws = RerouteWorkspace::default();
        let mut got = Topology::default();
        ws.materialize(&t, &dead_sw, &dead_cb, &mut got);
        let want = crate::topology::degrade::apply(&t, &dead_sw, &dead_cb);
        assert_eq!(got.nodes.len(), want.nodes.len());
        assert_eq!(got.switches.len(), want.switches.len());
        assert_eq!(got.num_levels, want.num_levels);
        assert_eq!(got.port_offsets, want.port_offsets);
        for (a, b) in got.switches.iter().zip(&want.switches) {
            assert_eq!(a.uuid, b.uuid);
            assert_eq!(a.level, b.level);
            assert_eq!(a.ports, b.ports);
        }
        for (a, b) in got.nodes.iter().zip(&want.nodes) {
            assert_eq!(a.uuid, b.uuid);
            assert_eq!(a.leaf, b.leaf);
            assert_eq!(a.leaf_port, b.leaf_port);
        }
    }
}
