//! # Dmodc — fault-resilient routing for fat-tree networks
//!
//! A reproduction of *"High-Quality Fault-Resiliency in Fat-Tree Networks"*
//! (Gliksberg et al., HOTI'19): the Dmodc closed-form routing algorithm for
//! Parallel Generalized Fat-Trees, the OpenSM baseline engines it is
//! evaluated against (Ftree, UPDN, MinHop, SSSP, Dmodk), the static
//! congestion-risk analysis used for Figure 2, the RLFT runtime sweep of
//! Figure 3, and a centralized fabric manager that reroutes on fault events.
//!
//! Layering (see DESIGN.md): this crate is the L3 rust coordinator; the
//! congestion-analysis hot loop is additionally available as an AOT-compiled
//! XLA artifact (authored in JAX/Pallas at build time) executed through
//! [`runtime`] — python is never on the request path.
//!
//! All six routing algorithms implement the
//! [`RoutingEngine`](routing::RoutingEngine) trait: stateful objects
//! owning their workspaces (allocation-free steady-state reroutes), with
//! a [`Capabilities`](routing::Capabilities) surface (alternative ports
//! for fast patching, cost-reusing validation, history-freedom) and a
//! name-based constructor registry ([`routing::registry`]). The fabric
//! manager, CLI, benches, and examples all go through the trait — adding
//! a seventh engine is one module plus one registry row.
//!
//! ```no_run
//! use dmodc::prelude::*;
//!
//! let topo = PgftParams::fig1().build();
//! let lft = route(Algo::Dmodc, &topo).expect("valid PGFT");
//! let risk = CongestionAnalyzer::new(&topo, &lft).all_to_all();
//! println!("A2A max congestion risk: {risk}");
//! ```

// Index-parallel loops over multiple same-shaped arrays are the idiom of
// the routing kernels; the iterator rewrites clippy suggests obscure the
// paper's per-index arithmetic. Everything else is denied in CI.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod fabric;
pub mod routing;
pub mod runtime;
pub mod topology;
pub mod util;

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::analysis::patterns::Pattern;
    pub use crate::analysis::CongestionAnalyzer;
    pub use crate::routing::{route, Algo, Capabilities, Lft, RoutingEngine};
    pub use crate::topology::degrade::{self, Equipment};
    pub use crate::topology::pgft::PgftParams;
    pub use crate::topology::rlft;
    pub use crate::topology::{Builder, NodeId, SwitchId, Topology};
    pub use crate::util::rng::Rng;
}
