//! Hot-path micro/meso benches (PERF-L3 in DESIGN.md): the pieces the
//! performance pass profiles and optimizes.
//!
//!   HOTPATH_FULL=1   benchmark at the full 8640-node scale
//!   BENCH_ITERS=n    repetitions per measurement

use dmodc::analysis::congestion::PermEngine;
use dmodc::analysis::paths::PathTensor;
use dmodc::analysis::{a2a, CongestionAnalyzer};
use dmodc::fabric::{events, FabricManager, ManagerConfig};
use dmodc::prelude::*;
use dmodc::routing::dmodc::Router;
use dmodc::routing::{common, route_unchecked};
use dmodc::runtime::{AnalysisExecutor, ArtifactRegistry};
use dmodc::util::table::{fmt_duration, Table};
use dmodc::util::time::bench;

fn main() {
    let full = std::env::var("HOTPATH_FULL").is_ok();
    let params = if full {
        PgftParams::paper_8640()
    } else {
        PgftParams::parse("16,9,12;1,4,6;1,1,1").unwrap()
    };
    let topo = params.build();
    println!(
        "hotpath on {} nodes / {} switches (threads={})",
        topo.nodes.len(),
        topo.switches.len(),
        dmodc::util::par::num_threads()
    );
    let mut tab = Table::new(&["stage", "median", "min"]);
    let mut add = |name: &str, s: dmodc::util::time::Sample| {
        tab.row(vec![
            name.to_string(),
            fmt_duration(s.median),
            fmt_duration(s.min),
        ]);
    };

    // Dmodc pipeline stages.
    add("dmodc: prep (groups)", bench(1, 5, || common::Prep::new(&topo)));
    let prep = common::Prep::new(&topo);
    add(
        "dmodc: costs+dividers (Alg 1, parallel)",
        bench(1, 5, || common::costs(&topo, &prep, common::DividerReduction::Max)),
    );
    add(
        "dmodc: costs+dividers (Alg 1, serial ref)",
        bench(1, 5, || {
            common::costs_serial(&topo, &prep, common::DividerReduction::Max)
        }),
    );
    let router = Router::new(&topo, Default::default());
    add(
        "dmodc: NIDs (Alg 2)",
        bench(1, 5, || {
            dmodc::routing::dmodc::topological_nids(&topo, &router.prep, &router.costs)
        }),
    );
    add("dmodc: routes (eqs 1-4)", bench(1, 5, || router.lft(&topo)));
    add("dmodc: full reroute", bench(1, 5, || route_unchecked(Algo::Dmodc, &topo)));
    add(
        "dmodc: full reroute (literal-eqs reference)",
        bench(1, 3, || {
            dmodc::routing::dmodc::route_reference(&topo, &Default::default())
        }),
    );
    {
        // Steady-state workspace reroute: buffers reused across events.
        let mut ws = dmodc::routing::RerouteWorkspace::default();
        let mut out = dmodc::routing::Lft::default();
        ws.reroute_into(&topo, &mut out); // warm
        add(
            "dmodc: workspace steady-state reroute",
            bench(1, 5, || {
                ws.reroute_into(&topo, &mut out);
                out.raw()[0]
            }),
        );
    }
    {
        // Single-cable fault/recovery flip: full pipeline vs the delta
        // tier (EXPERIMENTS.md §"Incremental reroute") on identical
        // transitions — the delta rows quantify what skipping the
        // clean LFT rows buys.
        use std::collections::HashSet;
        let cable = dmodc::topology::degrade::cables(&topo)[0];
        let fault: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
        let recover: HashSet<(SwitchId, u16)> = HashSet::new();
        let no_sw: HashSet<SwitchId> = HashSet::new();
        let mut ws = dmodc::routing::RerouteWorkspace::default();
        let mut degraded = Topology::default();
        let mut out = dmodc::routing::Lft::default();
        let mut touched = Vec::new();
        for dead in [&recover, &fault, &recover, &fault, &recover] {
            ws.materialize(&topo, &no_sw, dead, &mut degraded);
            ws.reroute_into(&degraded, &mut out); // warm both shapes
        }
        let mut flip = false;
        add(
            "dmodc: full reroute (single-cable flip)",
            bench(1, 5, || {
                flip = !flip;
                let dead = if flip { &fault } else { &recover };
                ws.materialize(&topo, &no_sw, dead, &mut degraded);
                ws.reroute_into(&degraded, &mut out);
                out.raw()[0]
            }),
        );
        // Re-warm through the delta entry point so prev-products exist.
        for dead in [&recover, &fault, &recover] {
            ws.materialize(&topo, &no_sw, dead, &mut degraded);
            ws.reroute_delta_into(&degraded, &mut out, &mut touched);
        }
        let mut flip = false;
        add(
            "dmodc: delta reroute (single-cable flip)",
            bench(1, 5, || {
                flip = !flip;
                let dead = if flip { &fault } else { &recover };
                ws.materialize(&topo, &no_sw, dead, &mut degraded);
                ws.reroute_delta_into(&degraded, &mut out, &mut touched);
                out.raw()[0]
            }),
        );
    }

    // Steady-state engine reroutes: every registered engine out of its
    // persistent workspace (the RoutingEngine redesign's hot path).
    for spec in dmodc::routing::registry::specs() {
        let mut eng = spec.build();
        let mut out = dmodc::routing::Lft::default();
        eng.route_into(&topo, &mut out); // warm
        add(
            &format!("engine: {} steady-state reroute", spec.name),
            bench(0, 3, || {
                eng.route_into(&topo, &mut out);
                out.raw()[0]
            }),
        );
    }

    // Analysis stages.
    let lft = route_unchecked(Algo::Dmodc, &topo);
    add("analysis: path tensor", bench(1, 5, || PathTensor::build(&topo, &lft)));
    let pt = PathTensor::build(&topo, &lft);
    // Incremental tensor maintenance: single-cable fault/recovery flip.
    {
        use std::collections::HashSet;
        let cable = dmodc::topology::degrade::cables(&topo)[0];
        let dead: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
        let dtopo = dmodc::topology::degrade::apply(&topo, &HashSet::new(), &dead);
        let dlft = route_unchecked(Algo::Dmodc, &dtopo);
        let dirty_fault = dlft.changed_rows(&lft);
        let dirty_recover = lft.changed_rows(&dlft);
        let mut inc = PathTensor::build(&topo, &lft);
        inc.update(&dtopo, &dlft, &dirty_fault); // warm both directions
        inc.update(&topo, &lft, &dirty_recover);
        let mut flip = false;
        add(
            "analysis: tensor update (single-cable flip)",
            bench(1, 5, || {
                flip = !flip;
                if flip {
                    inc.update(&dtopo, &dlft, &dirty_fault)
                } else {
                    inc.update(&topo, &lft, &dirty_recover)
                }
                .is_incremental() as u64
            }),
        );
    }
    let engine = PermEngine::new(&topo, &pt);
    let n = topo.nodes.len();
    add(
        "analysis: 100 random perms",
        bench(1, 3, || engine.random_perm_median(100, 1)),
    );
    add(
        "analysis: SP all shifts (naive)",
        bench(0, 3, || engine.shift_series_naive().len()),
    );
    {
        let block = dmodc::analysis::congestion::default_block(topo.num_ports());
        let mut series = Vec::new();
        add(
            &format!("analysis: SP all shifts (blocked K={block})"),
            bench(0, 3, || {
                engine.shift_series_blocked_into(block, &mut series);
                series[0]
            }),
        );
    }
    add("analysis: A2A exact", bench(0, 3, || a2a::all_to_all(&topo, &pt)));

    // Fabric manager end-to-end reaction (one switch fault).
    let victim = topo
        .switches
        .iter()
        .find(|s| s.level == 2)
        .map(|s| s.uuid)
        .unwrap();
    let mut mgr = FabricManager::new(topo.clone(), ManagerConfig::default());
    add(
        "fabric: fault reaction e2e",
        bench(1, 3, || {
            mgr.apply(&events::Event {
                at_ms: 1,
                kind: events::EventKind::SwitchDown(victim),
            });
            mgr.apply(&events::Event {
                at_ms: 2,
                kind: events::EventKind::SwitchUp(victim),
            })
            .reroute_secs
        }),
    );

    // AOT artifact dispatch (648-node registry shape), when available.
    let reg = ArtifactRegistry::default_location();
    if !reg.specs.is_empty() && !full {
        let t648 = rlft::build(648, 36);
        let l648 = route_unchecked(Algo::Dmodc, &t648);
        let an = CongestionAnalyzer::new(&t648, &l648);
        for variant in ["jnp", "pallas"] {
            if let Ok(Some(exe)) = AnalysisExecutor::bind(&reg, variant, &t648, an.paths()) {
                let mut rng = Rng::new(3);
                let perms: Vec<Vec<u32>> =
                    (0..exe.spec().b).map(|_| rng.permutation(648)).collect();
                let _ = exe.run(&perms[..1]); // warm
                add(
                    &format!("runtime: {variant} artifact batch({})", exe.spec().b),
                    bench(0, 3, || exe.run(&perms).unwrap().len()),
                );
            }
        }
        add(
            "runtime: native batch(64) @648",
            bench(0, 3, || {
                let mut rng = Rng::new(3);
                (0..64)
                    .map(|_| an.perm_max_load(&rng.permutation(648)))
                    .max()
            }),
        );
    }

    let _ = n;
    let _ = tab.write_csv("bench_results/hotpath.csv");
    print!("{}", tab.render());
}
