//! Figure 2 harness: maximum congestion risk under random topology
//! degradation, for every engine × pattern × equipment kind.
//!
//! The paper degrades an 8640-node blocking-4 PGFT with hundreds of
//! log-uniform throws and reports A2A / RP(1000-perm median) / SP(max over
//! all shifts) in log-log scale. Default scale here is a 1728-node
//! blocking-4 PGFT with fewer throws so `cargo bench` finishes in minutes;
//! environment knobs reproduce the full figure:
//!
//!   FIG2_FULL=1        use PGFT(3; 24,15,24; 1,6,8; 1,1,1) (8640 nodes)
//!   FIG2_THROWS=200    throws per equipment kind
//!   FIG2_RP=1000       RP samples
//!   FIG2_SEED=42
//!
//! Output: one row per (kind, throw, algo) plus an octave-binned summary
//! (geometric means — the log-log reading of the paper's plot), and CSVs
//! under bench_results/.

use dmodc::analysis::CongestionAnalyzer;
use dmodc::prelude::*;
use dmodc::routing::{route_unchecked, validity};
use dmodc::util::rng::log_uniform_amount;
use dmodc::util::table::Table;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let full = std::env::var("FIG2_FULL").is_ok();
    let mut params = if full {
        PgftParams::paper_8640()
    } else {
        PgftParams::parse("16,9,12;1,4,6;1,1,1").unwrap()
    };
    // Install-order UUIDs: aligns the shift ordering with Ftree's internal
    // order, the paper's fairness condition for SP (FIG2_SCRAMBLED=1 for
    // fabrication-scrambled UUIDs).
    if std::env::var("FIG2_SCRAMBLED").is_err() {
        params = params.with_uuid_mode(dmodc::topology::pgft::UuidMode::Sequential);
    }
    let throws = env_usize("FIG2_THROWS", if full { 100 } else { 24 });
    let rp_samples = env_usize("FIG2_RP", if full { 1000 } else { 100 });
    let seed = env_usize("FIG2_SEED", 42) as u64;
    let topo = params.build();
    println!(
        "fig2: {} nodes, {} switches, {} cables; {throws} throws/kind, RP={rp_samples}",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_cables()
    );

    let mut rows = Table::new(&[
        "kind", "removed", "algo", "valid", "A2A", "RP", "SP",
    ]);
    // (kind, octave, algo) -> (sum of ln(risk), count) per pattern.
    let mut summary: std::collections::BTreeMap<(String, u32, &'static str), ([f64; 3], usize)> =
        std::collections::BTreeMap::new();

    let mut rng = Rng::new(seed);
    for kind in [Equipment::Switches, Equipment::Links] {
        let kind_name = format!("{kind:?}").to_lowercase();
        let max = match kind {
            Equipment::Switches => degrade::removable_switches(&topo).len(),
            Equipment::Links => degrade::cables(&topo).len(),
        };
        for _ in 0..throws {
            let amount = log_uniform_amount(&mut rng, max);
            let degraded = match kind {
                Equipment::Switches => degrade::remove_random_switches(&topo, &mut rng, amount),
                Equipment::Links => degrade::remove_random_links(&topo, &mut rng, amount),
            };
            for algo in Algo::PAPER {
                let lft = route_unchecked(algo, &degraded);
                let valid = validity::check(&degraded, &lft).is_ok();
                if !valid {
                    rows.row(vec![
                        kind_name.clone(),
                        amount.to_string(),
                        algo.name().into(),
                        "false".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
                let an = CongestionAnalyzer::new(&degraded, &lft);
                let a2a = an.all_to_all();
                let rp = an.random_perm_median(rp_samples, seed ^ amount as u64);
                let sp = an.shift_max();
                rows.row(vec![
                    kind_name.clone(),
                    amount.to_string(),
                    algo.name().into(),
                    "true".into(),
                    a2a.to_string(),
                    rp.to_string(),
                    sp.to_string(),
                ]);
                let octave = (amount as f64).log2().max(0.0).floor() as u32;
                let e = summary
                    .entry((kind_name.clone(), octave, algo.name()))
                    .or_insert(([0.0; 3], 0));
                for (slot, v) in e.0.iter_mut().zip([a2a, rp, sp]) {
                    *slot += (v.max(1) as f64).ln();
                }
                e.1 += 1;
            }
        }
    }
    let _ = rows.write_csv("bench_results/fig2.csv");
    print!("{}", rows.render());

    let mut sum_tab = Table::new(&[
        "kind", "removed≈", "algo", "gm A2A", "gm RP", "gm SP", "n",
    ]);
    for ((kind, octave, algo), (lns, count)) in &summary {
        let gm = |i: usize| format!("{:.1}", (lns[i] / *count as f64).exp());
        sum_tab.row(vec![
            kind.clone(),
            format!("2^{octave}"),
            algo.to_string(),
            gm(0),
            gm(1),
            gm(2),
            count.to_string(),
        ]);
    }
    let _ = sum_tab.write_csv("bench_results/fig2_summary.csv");
    print!("{}", sum_tab.render());
    println!("rows → bench_results/fig2.csv, summary → bench_results/fig2_summary.csv");
}
