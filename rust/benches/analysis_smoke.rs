//! CI bench smoke for the analysis layer, written to `BENCH_analysis.json`
//! (schema `bench_analysis/v2`) so the analysis-perf trajectory is tracked
//! across PRs next to `BENCH_reroute.json` (see `.github/workflows/ci.yml`
//! and EXPERIMENTS.md §"Analysis perf" / §"Campaign fork perf").
//!
//! Measured quantities:
//! * tensor_full — a from-scratch `PathTensor` rebuild out of warm
//!   buffers (the fork-disabled campaign per-sample cost).
//! * tensor_update — the incremental `PathTensor::update` reaction to a
//!   single-cable fault/recovery flip (the risk-probe per-event cost),
//!   with the retraced-row fraction recorded.
//! * sp_naive vs sp_blocked — the all-shifts SP scan, one full tensor
//!   pass per shift vs the shift-blocked scan at the auto block size;
//!   `sp_blocked_speedup` is the headline bandwidth win.
//! * campaign — a small {engines × levels × seeds × patterns} grid
//!   through `analysis::campaign`, with baseline forking on vs off
//!   (`campaign_fork_speedup`, `fork_hit_rate`).
//! * per-level fork columns (schema v2) — Dmodc-only grids pinned to one
//!   degradation level each (0 / ~1 % / ~5 % of cables), forked vs
//!   from-scratch samples/s and the speedup; the paper's sub-1 % sweet
//!   spot is where the fork win peaks.
//!
//!   ANALYSIS_PGFT="16,9,12;1,4,6;1,1,1"   topology (default: 1728 nodes)
//!   BENCH_ANALYSIS_OUT=BENCH_analysis.json  output path

use dmodc::analysis::campaign::{self, CampaignConfig};
use dmodc::analysis::congestion::{default_block, PermEngine};
use dmodc::analysis::paths::{PathTensor, TensorUpdate};
use dmodc::prelude::*;
use dmodc::routing::registry;
use dmodc::util::time::{bench, now};
use std::collections::HashSet;

fn main() {
    let spec = std::env::var("ANALYSIS_PGFT").unwrap_or_else(|_| "16,9,12;1,4,6;1,1,1".into());
    let params = PgftParams::parse(&spec).expect("ANALYSIS_PGFT");
    let topo = params.build();
    let mut engine = registry::create(Algo::Dmodc);
    let lft = engine.route_once(&topo);
    println!(
        "analysis smoke on {} nodes / {} switches / {} ports",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_ports()
    );

    // --- tensor: full rebuild out of warm buffers ---
    let mut tensor = PathTensor::build(&topo, &lft);
    let full = bench(1, 5, || {
        tensor.rebuild(&topo, &lft);
        tensor.raw()[0]
    });

    // --- tensor: incremental single-cable flip ---
    let cable = degrade::cables(&topo)[0];
    let dead: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
    let degraded = degrade::apply(&topo, &HashSet::new(), &dead);
    let lft_d = engine.route_once(&degraded);
    let dirty_fault = lft_d.changed_rows(&lft);
    let dirty_recover = lft.changed_rows(&lft_d);
    let rows_total = tensor.num_leaves * tensor.num_nodes;
    // Warm both directions (the first flip establishes history).
    tensor.update(&degraded, &lft_d, &dirty_fault);
    tensor.update(&topo, &lft, &dirty_recover);
    let mut flip = false;
    let mut retraced = 0usize;
    let mut incremental = true;
    let update = bench(1, 5, || {
        flip = !flip;
        let up = if flip {
            tensor.update(&degraded, &lft_d, &dirty_fault)
        } else {
            tensor.update(&topo, &lft, &dirty_recover)
        };
        match up {
            TensorUpdate::Incremental(st) => retraced = st.rows_retraced,
            TensorUpdate::Rebuilt(_) => incremental = false,
        }
        tensor.raw()[0]
    });

    // --- SP: naive vs shift-blocked ---
    tensor.rebuild(&topo, &lft);
    let pe = PermEngine::new(&topo, &tensor);
    let block = default_block(topo.num_ports());
    let naive = bench(1, 3, || pe.shift_series_naive());
    let mut series = Vec::new();
    let blocked = bench(1, 3, || {
        pe.shift_series_blocked_into(block, &mut series);
        series[0]
    });
    assert_eq!(
        pe.shift_series_naive(),
        series,
        "blocked scan must equal the naive scan"
    );

    // --- campaign throughput on a small grid, forked vs from-scratch ---
    let base_cfg = CampaignConfig {
        engines: Algo::ALL.to_vec(),
        equipment: Equipment::Links,
        levels: vec![0, 2, 8],
        seeds: vec![1, 2, 3],
        patterns: vec![
            Pattern::AllToAll,
            Pattern::RandomPermutation { samples: 50 },
            Pattern::ShiftPermutation,
        ],
        sp_block: 0,
        workers: 0,
        ..CampaignConfig::default()
    };
    let t0 = now();
    let (rows, stats) = campaign::run_with_stats(&topo, &base_cfg);
    let campaign_secs = t0.elapsed().as_secs_f64();
    let samples_per_s = rows.len() as f64 / campaign_secs.max(1e-9);
    let t0 = now();
    let unforked_rows = campaign::run(
        &topo,
        &CampaignConfig {
            fork: false,
            ..base_cfg.clone()
        },
    );
    let campaign_full_secs = t0.elapsed().as_secs_f64();
    assert_eq!(unforked_rows.len(), rows.len());
    let full_samples_per_s = unforked_rows.len() as f64 / campaign_full_secs.max(1e-9);

    // --- per-level fork columns: Dmodc at 0 / ~1 % / ~5 % of cables ---
    let n_cables = topo.num_cables();
    let fork_levels: Vec<usize> = vec![0, (n_cables / 100).max(1), (n_cables / 20).max(2)];
    let mut forked_sps = Vec::new();
    let mut unforked_sps = Vec::new();
    let mut level_hit_rates = Vec::new();
    for &level in &fork_levels {
        let cfg = CampaignConfig {
            engines: vec![Algo::Dmodc],
            equipment: Equipment::Links,
            levels: vec![level],
            seeds: (0..8).collect(),
            patterns: vec![Pattern::AllToAll, Pattern::ShiftPermutation],
            sp_block: 0,
            workers: 0,
            ..CampaignConfig::default()
        };
        let t0 = now();
        let (rows_f, st) = campaign::run_with_stats(&topo, &cfg);
        let secs_f = t0.elapsed().as_secs_f64();
        let t0 = now();
        let rows_u = campaign::run(
            &topo,
            &CampaignConfig {
                fork: false,
                ..cfg.clone()
            },
        );
        let secs_u = t0.elapsed().as_secs_f64();
        assert_eq!(rows_f.len(), rows_u.len());
        forked_sps.push(rows_f.len() as f64 / secs_f.max(1e-9));
        unforked_sps.push(rows_u.len() as f64 / secs_u.max(1e-9));
        level_hit_rates.push(st.fork_hit_rate());
    }
    let fmt_vec = |v: &[f64]| {
        let cells: Vec<String> = v.iter().map(|x| format!("{x:.3}")).collect();
        format!("[{}]", cells.join(", "))
    };
    let speedups: Vec<f64> = forked_sps
        .iter()
        .zip(&unforked_sps)
        .map(|(f, u)| f / u.max(1e-9))
        .collect();

    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench_analysis/v2\",\n",
            "  \"status\": \"ok\",\n",
            "  \"topology\": \"PGFT({spec})\",\n",
            "  \"nodes\": {nodes},\n",
            "  \"switches\": {switches},\n",
            "  \"ports\": {ports},\n",
            "  \"tensor_full_median_s\": {full:.6},\n",
            "  \"tensor_update_median_s\": {update:.6},\n",
            "  \"tensor_update_incremental\": {inc},\n",
            "  \"tensor_rows_total\": {rows_total},\n",
            "  \"tensor_update_rows_retraced\": {retraced},\n",
            "  \"tensor_update_speedup\": {tsp:.3},\n",
            "  \"sp_block\": {block},\n",
            "  \"sp_naive_median_s\": {naive:.6},\n",
            "  \"sp_blocked_median_s\": {blocked:.6},\n",
            "  \"sp_blocked_speedup\": {ssp:.3},\n",
            "  \"campaign_rows\": {crows},\n",
            "  \"campaign_secs\": {csecs:.3},\n",
            "  \"campaign_samples_per_s\": {cps:.2},\n",
            "  \"campaign_full_secs\": {cfsecs:.3},\n",
            "  \"campaign_full_samples_per_s\": {cfps:.2},\n",
            "  \"campaign_fork_speedup\": {cspd:.3},\n",
            "  \"fork_hit_rate\": {fhr:.4},\n",
            "  \"campaign_fork_levels\": {flv:?},\n",
            "  \"campaign_fork_hit_rate_per_level\": {flh},\n",
            "  \"campaign_forked_samples_per_s\": {ffs},\n",
            "  \"campaign_unforked_samples_per_s\": {fus},\n",
            "  \"campaign_fork_speedup_per_level\": {fsp}\n",
            "}}\n"
        ),
        spec = spec,
        nodes = topo.nodes.len(),
        switches = topo.switches.len(),
        ports = topo.num_ports(),
        full = full.median,
        update = update.median,
        inc = incremental,
        rows_total = rows_total,
        retraced = retraced,
        tsp = full.median / update.median.max(1e-12),
        block = block,
        naive = naive.median,
        blocked = blocked.median,
        ssp = naive.median / blocked.median.max(1e-12),
        crows = rows.len(),
        csecs = campaign_secs,
        cps = samples_per_s,
        cfsecs = campaign_full_secs,
        cfps = full_samples_per_s,
        cspd = samples_per_s / full_samples_per_s.max(1e-9),
        fhr = stats.fork_hit_rate(),
        flv = fork_levels,
        flh = fmt_vec(&level_hit_rates),
        ffs = fmt_vec(&forked_sps),
        fus = fmt_vec(&unforked_sps),
        fsp = fmt_vec(&speedups),
    );
    let out_path =
        std::env::var("BENCH_ANALYSIS_OUT").unwrap_or_else(|_| "BENCH_analysis.json".into());
    std::fs::write(&out_path, &json).expect("write BENCH_analysis.json");
    print!("{json}");
    println!("→ {out_path}");
}
