//! Figure 3 harness: full routing-algorithm runtime vs cluster size.
//!
//! The paper sweeps RLFT topologies up to many tens of thousands of nodes
//! on a Xeon E5-2680v3 and shows Dmodc 1–2 orders of magnitude faster than
//! the OpenSM engines, with SSSP slowest. We regenerate the same series
//! (absolute numbers shift with the host, orderings should not; the
//! RLFT construction's non-monotonic switch counts also reproduce the
//! "local erraticness" note).
//!
//! Beyond the paper's engines, two extra columns track the hot-path work
//! (EXPERIMENTS.md §Perf): `dmodc-seed` replays the pre-optimization
//! pipeline (fresh allocations, **serial** Algorithm 1, the seed's
//! already-parallel strength-reduced fill) — the honest baseline for the
//! ≥2× acceptance gate — and `dmodc-ws` is the steady-state workspace
//! reroute (buffers reused, parallel Algorithm 1). seed/ws is the speedup
//! of this optimization pass.
//!
//! Since the `RoutingEngine` redesign, every *benched* engine (the
//! paper's five — Dmodk is not part of Figure 3) also gets a
//! steady-state measurement through a persistent registry-constructed
//! engine (CSV rows `<algo>-ws`): cold-start construction vs
//! workspace-reusing reroute, the gap the trait exists to close for the
//! baseline engines.
//!
//!   FIG3_MAX=20736       largest node count
//!   FIG3_MAX_SLOW=5184   cap for the O(N·E log V)-ish engines
//!   FIG3_RADIX=36        switch radix
//!   BENCH_ITERS=3        timing repetitions
//!   DMODC_THREADS=n      worker threads (default: all cores)

use dmodc::prelude::*;
use dmodc::routing::common::{self, DividerReduction, Prep};
use dmodc::routing::dmodc::{topological_nids, Options, Router};
use dmodc::routing::{registry, route_unchecked, Lft};
use dmodc::util::table::{fmt_duration, Table};
use dmodc::util::time::bench;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The seed pipeline, stage for stage: freshly allocated `Prep`, serial
/// push-based Algorithm 1, Algorithm 2, and the seed's parallel
/// strength-reduced row fill. (Not `route_reference`, whose literal
/// per-destination equations are deliberately naive — benchmarking that
/// would overstate the optimization.)
fn seed_pipeline(topo: &Topology) -> Lft {
    let prep = Prep::new(topo);
    let costs = common::costs_serial(topo, &prep, DividerReduction::Max);
    let nids = topological_nids(topo, &prep, &costs);
    let router = Router {
        prep,
        costs,
        nids,
        opts: Options::default(),
    };
    router.lft(topo)
}

fn main() {
    let max = env_usize("FIG3_MAX", 20_736);
    let max_slow = env_usize("FIG3_MAX_SLOW", 5_184);
    let radix = env_usize("FIG3_RADIX", 36) as u32;
    let sizes: Vec<usize> = [72, 162, 324, 648, 1296, 2592, 5184, 10368, 20736, 41472]
        .into_iter()
        .filter(|&n| n <= max)
        .collect();
    println!("threads = {}", dmodc::util::par::num_threads());

    let mut tab = Table::new(&[
        "nodes", "switches", "dmodc", "dmodc-seed", "dmodc-ws", "ftree", "updn", "minhop", "sssp",
    ]);
    let mut csv = Table::new(&["nodes", "switches", "algo", "seconds"]);
    for &n in &sizes {
        let topo = rlft::build(n, radix);
        let mut cells = vec![n.to_string(), topo.switches.len().to_string()];
        for algo in [Algo::Dmodc, Algo::Ftree, Algo::Updn, Algo::MinHop, Algo::Sssp] {
            let slow = matches!(algo, Algo::Ftree | Algo::Updn | Algo::MinHop | Algo::Sssp);
            if slow && n > max_slow {
                cells.push("-".into());
                continue;
            }
            let s = bench(0, 3, || route_unchecked(algo, &topo));
            cells.push(fmt_duration(s.median));
            csv.row(vec![
                n.to_string(),
                topo.switches.len().to_string(),
                algo.name().into(),
                format!("{:.6}", s.median),
            ]);
            // Steady-state reroute through a persistent engine (workspace
            // reused across calls) — CSV row `<algo>-ws` for every engine.
            let mut eng = registry::create(algo);
            let mut out = Lft::default();
            eng.route_into(&topo, &mut out); // warm
            let w = bench(0, 3, || {
                eng.route_into(&topo, &mut out);
                out.raw()[0]
            });
            csv.row(vec![
                n.to_string(),
                topo.switches.len().to_string(),
                format!("{algo}-ws"),
                format!("{:.6}", w.median),
            ]);
            if algo == Algo::Dmodc {
                // Seed-pipeline baseline.
                let r = bench(0, 3, || seed_pipeline(&topo));
                cells.push(fmt_duration(r.median));
                csv.row(vec![
                    n.to_string(),
                    topo.switches.len().to_string(),
                    "dmodc-seed".into(),
                    format!("{:.6}", r.median),
                ]);
                cells.push(fmt_duration(w.median));
            }
        }
        tab.row(cells);
        println!("… {n} nodes done");
    }
    let _ = csv.write_csv("bench_results/fig3.csv");
    print!("{}", tab.render());
    println!("(median of 3; '-' = skipped above FIG3_MAX_SLOW; CSV → bench_results/fig3.csv)");
}
