//! CI bench smoke: the nodes-vs-latency reroute curve, written to
//! `BENCH_reroute.json` (schema `bench_reroute/v3`) so the perf
//! trajectory is tracked across PRs (see `.github/workflows/ci.yml` and
//! EXPERIMENTS.md §"Paper-scale reroute").
//!
//! Each curve entry is one PGFT preset (default fig1 → small →
//! paper_8640 → huge) measured at 1 and 8 worker threads:
//! * full — one steady-state fault reaction: in-place degraded topology
//!   materialization plus the full Dmodc pipeline
//!   (prep → Algorithm 1 → Algorithm 2 → route fill) out of a persistent
//!   `RerouteWorkspace`, alternating a spine fault with recovery so both
//!   the degraded and intact shapes stay warm. Per-stage wall times
//!   (`RerouteTimings`) of the final measured reaction ride along.
//! * delta — the same alternation for a *single cable* fault/recovery
//!   through `reroute_delta_into`; `tier_fired` records that the
//!   measurement really exercised the incremental tier.
//! * seed_baseline_median_s — the pre-optimization pipeline (fresh
//!   allocations + serial Algorithm 1) for the speedup baseline.
//! * reference_identical — on presets ≤ 10k nodes, the workspace output
//!   is compared byte-for-byte against `route_reference` at every
//!   measured thread count (`null` when skipped for cost).
//!
//! Selection:
//!   --preset a,b,..      named presets (fig1|small|paper_8640|huge),
//!                        also via REROUTE_PRESETS
//!   REROUTE_PGFT="m;w;p" adds one custom topology entry
//!   (neither given: the full default curve)
//! Knobs:
//!   BENCH_ITERS=5                          repetitions per measurement
//!   BENCH_REROUTE_OUT=BENCH_reroute.json   output path
//!   REROUTE_CEILING_S=12.0   fail (exit 1) if the largest preset's
//!                            max-thread full-reroute median exceeds this

use dmodc::prelude::*;
use dmodc::routing::common::{self, DividerReduction, Prep};
use dmodc::routing::dmodc::{route_reference, topological_nids, Options, Router};
use dmodc::routing::{Lft, RerouteTimings, RerouteWorkspace};
use dmodc::util::par;
use dmodc::util::time::bench;
use std::collections::HashSet;

/// Measured thread counts (the work-stealing sweep of the curve).
const THREAD_COUNTS: [usize; 2] = [1, 8];

/// Above this node count: single measured iteration for the expensive
/// serial parts and no `route_reference` comparison (covered by the
/// `#[ignore]` equivalence tests instead).
const BIG_NODES: usize = 10_000;

/// The seed pipeline, stage for stage (see fig3_runtime.rs for rationale).
fn seed_pipeline(topo: &Topology) -> Lft {
    let prep = Prep::new(topo);
    let costs = common::costs_serial(topo, &prep, DividerReduction::Max);
    let nids = topological_nids(topo, &prep, &costs);
    let router = Router {
        prep,
        costs,
        nids,
        opts: Options::default(),
    };
    router.lft(topo)
}

struct FullSample {
    threads: usize,
    median_s: f64,
    min_s: f64,
    stages: RerouteTimings,
}

struct DeltaSample {
    threads: usize,
    median_s: f64,
    min_s: f64,
    tier_fired: bool,
}

fn measure_full(topo: &Topology, threads: usize, iters: usize) -> FullSample {
    par::set_threads(Some(threads));
    let spine = topo
        .switches
        .iter()
        .enumerate()
        .rev()
        .find(|(_, s)| s.level > 0)
        .map(|(i, _)| i as SwitchId)
        .expect("topology has a spine");
    let fault: HashSet<SwitchId> = [spine].into_iter().collect();
    let recover: HashSet<SwitchId> = HashSet::new();
    let no_cables: HashSet<(SwitchId, u16)> = HashSet::new();
    let mut ws = RerouteWorkspace::default();
    let mut degraded = Topology::default();
    let mut out = Lft::default();
    // Warm both shapes (and the worker pool / per-worker scratch).
    for dead in [&fault, &recover] {
        ws.materialize(topo, dead, &no_cables, &mut degraded);
        ws.reroute_into(&degraded, &mut out);
    }
    let mut flip = false;
    let s = bench(1, iters, || {
        flip = !flip;
        let dead = if flip { &fault } else { &recover };
        ws.materialize(topo, dead, &no_cables, &mut degraded);
        ws.reroute_into(&degraded, &mut out);
        out.raw()[0]
    });
    let stages = ws.timings();
    par::set_threads(None);
    FullSample {
        threads,
        median_s: s.median,
        min_s: s.min,
        stages,
    }
}

/// Single-cable fault/recovery reaction through the delta tier.
fn measure_delta(topo: &Topology, threads: usize, iters: usize) -> DeltaSample {
    par::set_threads(Some(threads));
    // First leaf uplink cable: the canonical single-cable throw.
    let cable = dmodc::topology::degrade::cables(topo)[0];
    let fault: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
    let recover: HashSet<(SwitchId, u16)> = HashSet::new();
    let no_switches: HashSet<SwitchId> = HashSet::new();
    let mut ws = RerouteWorkspace::default();
    let mut degraded = Topology::default();
    let mut out = Lft::default();
    let mut touched = Vec::new();
    // Warm both shapes through the delta entry point (the first call is
    // a NoHistory full fill; subsequent flips are delta transitions).
    for dead in [&recover, &fault, &recover] {
        ws.materialize(topo, &no_switches, dead, &mut degraded);
        ws.reroute_delta_into(&degraded, &mut out, &mut touched);
    }
    let mut flip = false;
    let mut all_delta = true;
    let s = bench(1, iters, || {
        flip = !flip;
        let dead = if flip { &fault } else { &recover };
        ws.materialize(topo, &no_switches, dead, &mut degraded);
        let outcome = ws.reroute_delta_into(&degraded, &mut out, &mut touched);
        all_delta &= outcome.is_delta();
        out.raw()[0]
    });
    par::set_threads(None);
    DeltaSample {
        threads,
        median_s: s.median,
        min_s: s.min,
        tier_fired: all_delta,
    }
}

/// Byte-compare the workspace output against `route_reference` at every
/// measured thread count.
fn reference_identical(topo: &Topology) -> bool {
    let want = route_reference(topo, &Options::default());
    let mut ok = true;
    for &threads in &THREAD_COUNTS {
        par::set_threads(Some(threads));
        let mut ws = RerouteWorkspace::default();
        let mut out = Lft::default();
        ws.reroute_into(topo, &mut out);
        ok &= out.raw() == want.raw();
        par::set_threads(None);
    }
    ok
}

struct Entry {
    name: String,
    spec: String,
    nodes: usize,
    switches: usize,
    seed_median_s: f64,
    full: Vec<FullSample>,
    delta: Vec<DeltaSample>,
    reference_identical: Option<bool>,
}

fn run_entry(name: &str, params: &PgftParams) -> Entry {
    let topo = params.build();
    let nodes = topo.nodes.len();
    let big = nodes > BIG_NODES;
    let iters = if big { 3 } else { 5 };
    println!(
        "preset {name}: {nodes} nodes / {} switches (LFT {} MiB)",
        topo.switches.len(),
        topo.switches.len() * nodes * 2 / (1 << 20)
    );
    // The seed baseline is serial and expensive at scale: one measured
    // run there (BENCH_ITERS still overrides).
    let seed = if big {
        bench(0, 1, || seed_pipeline(&topo))
    } else {
        bench(1, 3, || seed_pipeline(&topo))
    };
    let full: Vec<FullSample> = THREAD_COUNTS
        .iter()
        .map(|&t| measure_full(&topo, t, iters))
        .collect();
    let delta: Vec<DeltaSample> = THREAD_COUNTS
        .iter()
        .map(|&t| measure_delta(&topo, t, iters))
        .collect();
    let reference = if big {
        None
    } else {
        Some(reference_identical(&topo))
    };
    for f in &full {
        println!(
            "  full t{}: median {:.4}s (prep {:.4} costs {:.4} nids {:.4} fill {:.4})",
            f.threads,
            f.median_s,
            f.stages.prep_s,
            f.stages.costs_s,
            f.stages.nids_s,
            f.stages.fill_s
        );
    }
    for d in &delta {
        println!(
            "  delta t{}: median {:.4}s (tier_fired {})",
            d.threads, d.median_s, d.tier_fired
        );
    }
    Entry {
        name: name.to_string(),
        spec: params.to_string(),
        nodes,
        switches: topo.switches.len(),
        seed_median_s: seed.median,
        full,
        delta,
        reference_identical: reference,
    }
}

fn entry_json(e: &Entry) -> String {
    let full: Vec<String> = e
        .full
        .iter()
        .map(|f| {
            format!(
                concat!(
                    "        {{ \"threads\": {}, \"median_s\": {:.6}, \"min_s\": {:.6},\n",
                    "          \"stages\": {{ \"prep_s\": {:.6}, \"costs_s\": {:.6}, ",
                    "\"nids_s\": {:.6}, \"fill_s\": {:.6} }} }}"
                ),
                f.threads,
                f.median_s,
                f.min_s,
                f.stages.prep_s,
                f.stages.costs_s,
                f.stages.nids_s,
                f.stages.fill_s
            )
        })
        .collect();
    let delta: Vec<String> = e
        .delta
        .iter()
        .map(|d| {
            format!(
                "        {{ \"threads\": {}, \"median_s\": {:.6}, \"min_s\": {:.6}, \"tier_fired\": {} }}",
                d.threads, d.median_s, d.min_s, d.tier_fired
            )
        })
        .collect();
    let reference = match e.reference_identical {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    };
    format!(
        concat!(
            "    {{\n",
            "      \"preset\": \"{name}\",\n",
            "      \"topology\": \"PGFT({spec})\",\n",
            "      \"nodes\": {nodes},\n",
            "      \"switches\": {switches},\n",
            "      \"lft_bytes\": {lft},\n",
            "      \"seed_baseline_median_s\": {seed:.6},\n",
            "      \"full\": [\n{full}\n      ],\n",
            "      \"delta\": [\n{delta}\n      ],\n",
            "      \"reference_identical\": {reference}\n",
            "    }}"
        ),
        name = e.name,
        spec = e.spec,
        nodes = e.nodes,
        switches = e.switches,
        lft = e.switches * e.nodes * 2,
        seed = e.seed_median_s,
        full = full.join(",\n"),
        delta = delta.join(",\n"),
        reference = reference,
    )
}

/// `--preset a,b` / `--preset=a,b` from the post-`--` bench args.
fn preset_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--preset" {
            return it.next().cloned();
        }
        if let Some(v) = a.strip_prefix("--preset=") {
            return Some(v.to_string());
        }
    }
    std::env::var("REROUTE_PRESETS").ok()
}

fn main() {
    let mut selection: Vec<(String, PgftParams)> = Vec::new();
    if let Some(list) = preset_arg() {
        for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let p = PgftParams::preset(name).unwrap_or_else(|e| panic!("{e}"));
            selection.push((name.to_string(), p));
        }
    }
    if let Ok(spec) = std::env::var("REROUTE_PGFT") {
        let p = PgftParams::parse(&spec).expect("REROUTE_PGFT");
        selection.push(("custom".to_string(), p));
    }
    if selection.is_empty() {
        for name in ["fig1", "small", "paper_8640", "huge"] {
            selection.push((name.to_string(), PgftParams::preset(name).unwrap()));
        }
    }

    let host_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "reroute smoke: {} curve entries (host threads {host_threads})",
        selection.len()
    );

    let entries: Vec<Entry> = selection
        .iter()
        .map(|(name, p)| run_entry(name, p))
        .collect();

    // Wall-clock ceiling: largest entry, max measured thread count.
    let ceiling: Option<f64> = std::env::var("REROUTE_CEILING_S")
        .ok()
        .and_then(|v| v.parse().ok());
    let largest = entries.iter().max_by_key(|e| e.nodes).expect("entries");
    let largest_full = largest
        .full
        .iter()
        .max_by_key(|f| f.threads)
        .expect("full samples")
        .median_s;
    let ceiling_ok = ceiling.is_none_or(|c| largest_full <= c);

    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench_reroute/v3\",\n",
            "  \"status\": \"ok\",\n",
            "  \"host_threads\": {host},\n",
            "  \"thread_counts\": [1, 8],\n",
            "  \"curve\": [\n{curve}\n  ],\n",
            "  \"ceiling_s\": {ceiling},\n",
            "  \"ceiling_preset\": \"{cpreset}\",\n",
            "  \"ceiling_ok\": {cok}\n",
            "}}\n"
        ),
        host = host_threads,
        curve = entries.iter().map(entry_json).collect::<Vec<_>>().join(",\n"),
        ceiling = ceiling.map_or("null".to_string(), |c| format!("{c:.3}")),
        cpreset = largest.name,
        cok = ceiling_ok,
    );
    let out_path =
        std::env::var("BENCH_REROUTE_OUT").unwrap_or_else(|_| "BENCH_reroute.json".into());
    std::fs::write(&out_path, &json).expect("write BENCH_reroute.json");
    print!("{json}");
    println!("→ {out_path}");

    if let Some(bad) = entries
        .iter()
        .find(|e| e.reference_identical == Some(false))
    {
        eprintln!("FAIL: preset {} diverged from route_reference", bad.name);
        std::process::exit(1);
    }
    if !ceiling_ok {
        eprintln!(
            "FAIL: {} full reroute median {largest_full:.3}s exceeds REROUTE_CEILING_S {:.3}s",
            largest.name,
            ceiling.unwrap()
        );
        std::process::exit(1);
    }
}
