//! CI bench smoke: median full-reroute latency on a mid-size PGFT at 1 and
//! N worker threads, written to `BENCH_reroute.json` so the perf
//! trajectory is tracked across PRs (see `.github/workflows/ci.yml` and
//! EXPERIMENTS.md §Perf).
//!
//! Measured quantities:
//! * full — one steady-state fault reaction: in-place degraded topology
//!   materialization plus the full Dmodc pipeline
//!   (prep → Algorithm 1 → Algorithm 2 → route fill) out of a persistent
//!   `RerouteWorkspace`, alternating a spine fault with recovery so both
//!   the degraded and intact shapes stay warm.
//! * delta — the same alternation for a *single cable* fault/recovery
//!   through `reroute_delta_into` (EXPERIMENTS.md §"Incremental
//!   reroute"): products rebuilt, dirty rows diffed, only those rows
//!   refilled. The `delta_*` columns sit next to the full-reroute
//!   baseline so the delta win is tracked per PR; `delta_tier_fired`
//!   records that the measurement really exercised the incremental
//!   tier (not a silent fallback).
//!
//! `seed_baseline_median_s` times the pre-optimization pipeline (fresh
//! allocations + serial Algorithm 1 + the seed's parallel
//! strength-reduced fill) on the intact topology for the speedup
//! baseline.
//!
//!   REROUTE_PGFT="24,15,24;1,6,8;1,1,1"   topology (default: 8640 nodes)
//!   BENCH_ITERS=5                          repetitions per measurement
//!   BENCH_REROUTE_OUT=BENCH_reroute.json   output path

use dmodc::prelude::*;
use dmodc::routing::common::{self, DividerReduction, Prep};
use dmodc::routing::dmodc::{topological_nids, Options, Router};
use dmodc::routing::{Lft, RerouteWorkspace};
use dmodc::util::par;
use dmodc::util::time::bench;
use std::collections::HashSet;

/// The seed pipeline, stage for stage (see fig3_runtime.rs for rationale).
fn seed_pipeline(topo: &Topology) -> Lft {
    let prep = Prep::new(topo);
    let costs = common::costs_serial(topo, &prep, DividerReduction::Max);
    let nids = topological_nids(topo, &prep, &costs);
    let router = Router {
        prep,
        costs,
        nids,
        opts: Options::default(),
    };
    router.lft(topo)
}

fn median_reroute_secs(topo: &Topology, threads: usize) -> (f64, f64) {
    par::set_threads(Some(threads));
    let spine = topo
        .switches
        .iter()
        .enumerate()
        .rev()
        .find(|(_, s)| s.level > 0)
        .map(|(i, _)| i as SwitchId)
        .expect("topology has a spine");
    let fault: HashSet<SwitchId> = [spine].into_iter().collect();
    let recover: HashSet<SwitchId> = HashSet::new();
    let no_cables: HashSet<(SwitchId, u16)> = HashSet::new();
    let mut ws = RerouteWorkspace::default();
    let mut degraded = Topology::default();
    let mut out = Lft::default();
    // Warm both shapes (and the worker pool / per-worker scratch).
    for dead in [&fault, &recover, &fault, &recover] {
        ws.materialize(topo, dead, &no_cables, &mut degraded);
        ws.reroute_into(&degraded, &mut out);
    }
    let mut flip = false;
    let s = bench(1, 5, || {
        flip = !flip;
        let dead = if flip { &fault } else { &recover };
        ws.materialize(topo, dead, &no_cables, &mut degraded);
        ws.reroute_into(&degraded, &mut out);
        out.raw()[0]
    });
    par::set_threads(None);
    (s.median, s.min)
}

/// Single-cable fault/recovery reaction through the delta tier.
/// Returns (median, min, delta_tier_fired_on_every_measured_step).
fn median_delta_secs(topo: &Topology, threads: usize) -> (f64, f64, bool) {
    par::set_threads(Some(threads));
    // First leaf uplink cable: the canonical single-cable throw.
    let cable = dmodc::topology::degrade::cables(topo)[0];
    let fault: HashSet<(SwitchId, u16)> = [cable].into_iter().collect();
    let recover: HashSet<(SwitchId, u16)> = HashSet::new();
    let no_switches: HashSet<SwitchId> = HashSet::new();
    let mut ws = RerouteWorkspace::default();
    let mut degraded = Topology::default();
    let mut out = Lft::default();
    let mut touched = Vec::new();
    // Warm both shapes through the delta entry point (the first call is
    // a NoHistory full fill; subsequent flips are delta transitions).
    for dead in [&recover, &fault, &recover, &fault, &recover] {
        ws.materialize(topo, &no_switches, dead, &mut degraded);
        ws.reroute_delta_into(&degraded, &mut out, &mut touched);
    }
    let mut flip = false;
    let mut all_delta = true;
    let s = bench(1, 5, || {
        flip = !flip;
        let dead = if flip { &fault } else { &recover };
        ws.materialize(topo, &no_switches, dead, &mut degraded);
        let outcome = ws.reroute_delta_into(&degraded, &mut out, &mut touched);
        all_delta &= outcome.is_delta();
        out.raw()[0]
    });
    par::set_threads(None);
    (s.median, s.min, all_delta)
}

fn main() {
    let spec = std::env::var("REROUTE_PGFT").unwrap_or_else(|_| "24,15,24;1,6,8;1,1,1".into());
    let params = PgftParams::parse(&spec).expect("REROUTE_PGFT");
    let topo = params.build();
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n_threads = par::num_threads().max(2);
    println!(
        "reroute smoke on {} nodes / {} switches (host threads {host_threads})",
        topo.nodes.len(),
        topo.switches.len()
    );

    let reference = bench(1, 3, || seed_pipeline(&topo));
    let (m1, min1) = median_reroute_secs(&topo, 1);
    let (mn, minn) = median_reroute_secs(&topo, n_threads);
    let (d1, dmin1, d1_fired) = median_delta_secs(&topo, 1);
    let (dn, dminn, dn_fired) = median_delta_secs(&topo, n_threads);

    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench_reroute/v2\",\n",
            "  \"topology\": \"PGFT({spec})\",\n",
            "  \"nodes\": {nodes},\n",
            "  \"switches\": {switches},\n",
            "  \"host_threads\": {host},\n",
            "  \"seed_baseline_median_s\": {refm:.6},\n",
            "  \"threads_1\": {{ \"median_s\": {m1:.6}, \"min_s\": {min1:.6} }},\n",
            "  \"threads_n\": {{ \"n\": {nt}, \"median_s\": {mn:.6}, \"min_s\": {minn:.6} }},\n",
            "  \"delta_threads_1\": {{ \"median_s\": {d1:.6}, \"min_s\": {dmin1:.6} }},\n",
            "  \"delta_threads_n\": {{ \"n\": {nt}, \"median_s\": {dn:.6}, \"min_s\": {dminn:.6} }},\n",
            "  \"delta_tier_fired\": {fired},\n",
            "  \"speedup_n_vs_1\": {sp1:.3},\n",
            "  \"speedup_n_vs_seed_baseline\": {spr:.3},\n",
            "  \"delta_speedup_vs_full_t1\": {dsp1:.3},\n",
            "  \"delta_speedup_vs_full_tn\": {dspn:.3}\n",
            "}}\n"
        ),
        spec = spec,
        nodes = topo.nodes.len(),
        switches = topo.switches.len(),
        host = host_threads,
        refm = reference.median,
        m1 = m1,
        min1 = min1,
        nt = n_threads,
        mn = mn,
        minn = minn,
        d1 = d1,
        dmin1 = dmin1,
        dn = dn,
        dminn = dminn,
        fired = d1_fired && dn_fired,
        sp1 = m1 / mn.max(1e-12),
        spr = reference.median / mn.max(1e-12),
        dsp1 = m1 / d1.max(1e-12),
        dspn = mn / dn.max(1e-12),
    );
    let out_path =
        std::env::var("BENCH_REROUTE_OUT").unwrap_or_else(|_| "BENCH_reroute.json".into());
    std::fs::write(&out_path, &json).expect("write BENCH_reroute.json");
    print!("{json}");
    println!("→ {out_path}");
}
