//! Ablation benches for the design choices the paper calls out (§3.1):
//!
//! * ABL-RED — divider reduction: `max` (paper) vs `first-path` (the
//!   alternative the paper reports as showing "little to no change in
//!   route quality under random degradation"). We quantify that claim
//!   under light/moderate degradation.
//! * ABL-NID — topological NIDs (Algorithm 2) vs flat leaf-UUID numbering
//!   on a *fabrication-scrambled* fabric (where UUID order ≠ physical
//!   order — exactly the situation Algorithm 2 exists for). Each variant's
//!   SP risk is measured over the node ordering it publishes, since "Dmodc
//!   can provide optimal results for shift patterns which respect such an
//!   ordering".

use dmodc::analysis::CongestionAnalyzer;
use dmodc::prelude::*;
use dmodc::routing::common::DividerReduction;
use dmodc::routing::dmodc::{NidOrder, Options, Router};
use dmodc::routing::validity;
use dmodc::topology::pgft::UuidMode;
use dmodc::util::table::Table;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Node ordering published by a router: position sorted by assigned NID.
fn published_order(router: &Router, n: usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&i| router.nids[i as usize]);
    order
}

fn main() {
    let throws = env_usize("ABL_THROWS", 12);
    let rp = env_usize("ABL_RP", 100);

    // ---- ABL-RED: divider reduction under degradation ------------------
    let params = PgftParams::parse("16,9,12;1,4,6;1,1,1")
        .unwrap()
        .with_uuid_mode(UuidMode::Sequential);
    let topo = params.build();
    println!(
        "ABL-RED on {} nodes / {} switches; {throws} throws per level",
        topo.nodes.len(),
        topo.switches.len()
    );
    let mut red_tab = Table::new(&[
        "degradation",
        "reduction",
        "gm A2A",
        "gm RP",
        "gm SP",
        "identical LFTs",
    ]);
    for (label, amount) in [("intact", 0usize), ("light (8 sw)", 8), ("moderate (20 sw)", 20)] {
        let mut lns = [[0.0f64; 3]; 2];
        let mut count = 0usize;
        let mut identical = 0usize;
        let mut rng = Rng::new(2025);
        let reps = if amount == 0 { 1 } else { throws };
        for _ in 0..reps {
            let degraded = degrade::remove_random_switches(&topo, &mut rng, amount);
            let lfts: Vec<_> = [DividerReduction::Max, DividerReduction::FirstPath]
                .iter()
                .map(|&reduction| {
                    dmodc::routing::dmodc::route(
                        &degraded,
                        &Options {
                            reduction,
                            nid_order: NidOrder::Topological,
                        },
                    )
                })
                .collect();
            if validity::check(&degraded, &lfts[0]).is_err() {
                continue;
            }
            if lfts[0].raw() == lfts[1].raw() {
                identical += 1;
            }
            for (slot, lft) in lns.iter_mut().zip(&lfts) {
                let an = CongestionAnalyzer::new(&degraded, lft);
                for (s, v) in slot.iter_mut().zip([
                    an.all_to_all(),
                    an.random_perm_median(rp, 3),
                    an.shift_max(),
                ]) {
                    *s += (v.max(1) as f64).ln();
                }
            }
            count += 1;
        }
        for (vi, name) in ["max (paper)", "first-path"].iter().enumerate() {
            if count == 0 {
                continue;
            }
            let gm = |i: usize| format!("{:.1}", (lns[vi][i] / count as f64).exp());
            red_tab.row(vec![
                label.to_string(),
                name.to_string(),
                gm(0),
                gm(1),
                gm(2),
                format!("{identical}/{count}"),
            ]);
        }
    }
    print!("{}", red_tab.render());
    let _ = red_tab.write_csv("bench_results/ablation_reduction.csv");

    // ---- ABL-NID: Algorithm 2 vs flat UUID order (scrambled fabric) ----
    let scrambled = PgftParams::parse("16,9,12;1,4,6;1,1,1")
        .unwrap()
        .with_uuid_mode(UuidMode::Scrambled)
        .build();
    println!("\nABL-NID on a fabrication-scrambled fabric (UUID order ≠ physical):");
    let mut nid_tab = Table::new(&[
        "NID assignment",
        "SP over published order",
        "SP over physical order",
    ]);
    for (name, nid_order) in [
        ("Algorithm 2 (paper)", NidOrder::Topological),
        ("flat UUID order", NidOrder::UuidFlat),
    ] {
        let router = Router::new(
            &scrambled,
            Options {
                reduction: DividerReduction::Max,
                nid_order,
            },
        );
        let lft = router.lft(&scrambled);
        let an = CongestionAnalyzer::new(&scrambled, &lft);
        let order = published_order(&router, scrambled.nodes.len());
        nid_tab.row(vec![
            name.to_string(),
            an.shift_max_ordered(&order).to_string(),
            an.shift_max().to_string(),
        ]);
    }
    print!("{}", nid_tab.render());
    let _ = nid_tab.write_csv("bench_results/ablation_nid.csv");
    println!(
        "expected: Algorithm 2's published order recovers near-optimal SP even on a\n\
         scrambled fabric; a flat UUID order cannot (its clusters are not contiguous)."
    );
}
