//! Loom models for the worker-pool job-handoff lifecycle.
//!
//! Each model constructs a fresh instance [`Pool`] inside the iteration
//! (loom requires all synchronization objects to be born under its
//! scheduler) and ends with [`Pool::shutdown`] so every spawned thread
//! terminates — loom rejects explorations that leak live threads.
//!
//! Run with:
//!
//!     RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 cargo test --release
//!
//! What the models prove, over *every* interleaving loom explores:
//!
//! * `chunk_claiming_exactly_once` — the Relaxed atomic cursor hands each
//!   index to exactly one participant (the ordering table's claim that
//!   RMW atomicity alone suffices for disjointness);
//! * `two_consecutive_regions_handoff` — the seq-numbered publication
//!   protocol never double-runs or skips a job when a region is submitted
//!   while workers are still parking from the previous one;
//! * `nested_region_runs_inline` — a body opening another region runs it
//!   inline on the calling thread: no deadlock, every inner index once;
//! * `worker_panic_propagates` — a panicking body surfaces as a panic on
//!   the submitting thread and the pool stays usable afterwards.
//!
//! And for the fabric service's epoch-publication surface
//! (`util::sync::Published`, the double-buffered `Arc` swap behind
//! `fabric::lft_store::FabricReader`):
//!
//! * `published_handoff_never_tears_and_is_monotonic` — a reader racing
//!   a writer's publications only ever observes complete snapshots, and
//!   the observed epoch sequence never goes backwards;
//! * `published_concurrent_writers_serialize` — two racing `publish`
//!   calls serialize on the internal writer lock: both land, the final
//!   epoch counts both, and the final snapshot is one of the two whole
//!   payloads.

#![cfg(loom)]

use dmodc_loom::util::par::Pool;
use dmodc_loom::util::sync::Published;
use loom::sync::Arc;
use loom::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn chunk_claiming_exactly_once() {
    loom::model(|| {
        let pool = Pool::new();
        let n = 3;
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        {
            let hits = Arc::clone(&hits);
            // 3 participants (submitter + 2 workers) racing a 3-index range.
            pool.parallel_for_chunked_with(3, n, 1, move |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} not claimed exactly once");
        }
        pool.shutdown();
    });
}

#[test]
fn two_consecutive_regions_handoff() {
    loom::model(|| {
        let pool = Pool::new();
        let n = 2;
        for round in 0..2u64 {
            let total = Arc::new(AtomicUsize::new(0));
            {
                let total = Arc::clone(&total);
                pool.parallel_for_chunked_with(2, n, 1, move |i| {
                    total.fetch_add(i + 1, Ordering::Relaxed);
                });
            }
            assert_eq!(
                total.load(Ordering::Relaxed),
                n * (n + 1) / 2,
                "round {round} lost or double-ran an index"
            );
        }
        pool.shutdown();
    });
}

#[test]
fn nested_region_runs_inline() {
    loom::model(|| {
        let pool = Pool::new();
        let n = 2;
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n * n).map(|_| AtomicUsize::new(0)).collect());
        {
            let hits = Arc::clone(&hits);
            let pool_ref = &pool;
            pool.parallel_for_chunked_with(2, n, 1, move |i| {
                let hits = Arc::clone(&hits);
                // Nested region: must run inline on this thread, never
                // touching the (busy) pool slot — a deadlock here would
                // show up as a loom exploration that cannot terminate.
                pool_ref.parallel_for_chunked_with(2, n, 1, move |j| {
                    hits[i * n + j].fetch_add(1, Ordering::Relaxed);
                });
            });
        }
        for (k, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "inner index {k} not run exactly once");
        }
        pool.shutdown();
    });
}

#[test]
fn published_handoff_never_tears_and_is_monotonic() {
    loom::model(|| {
        // Payload invariant: every element equals the publishing epoch.
        // A torn snapshot would mix elements of different epochs.
        let p = Arc::new(Published::new(Arc::new(vec![0usize; 3])));
        let reader = {
            let p = Arc::clone(&p);
            loom::thread::spawn(move || {
                let mut last = 0usize;
                for _ in 0..2 {
                    let v = p.load();
                    let first = v[0];
                    assert!(
                        v.iter().all(|&x| x == first),
                        "torn snapshot: {v:?}"
                    );
                    assert!(first >= last, "epoch went backwards: {first} < {last}");
                    last = first;
                }
            })
        };
        for e in 1..=2usize {
            p.publish(Arc::new(vec![e; 3]));
        }
        reader.join().unwrap();
    });
}

#[test]
fn published_concurrent_writers_serialize() {
    loom::model(|| {
        let p = Arc::new(Published::new(Arc::new(vec![0usize; 2])));
        let w = {
            let p = Arc::clone(&p);
            loom::thread::spawn(move || {
                p.publish(Arc::new(vec![1usize; 2]));
            })
        };
        p.publish(Arc::new(vec![2usize; 2]));
        w.join().unwrap();
        assert_eq!(p.epoch(), 2, "both publications must land");
        let v = p.load();
        assert!(v[0] == v[1], "torn snapshot: {v:?}");
        assert!(v[0] == 1 || v[0] == 2, "final snapshot must be a published one");
    });
}

#[test]
fn worker_panic_propagates() {
    loom::model(|| {
        let pool = Pool::new();
        let n = 2;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for_chunked_with(2, n, 1, |i| {
                if i == 1 {
                    panic!("intentional model panic");
                }
            });
        }));
        assert!(r.is_err(), "a panicking body must propagate to the submitter");
        // The pool survives the panicked region: the next region still
        // completes and observes every index.
        let total = Arc::new(AtomicUsize::new(0));
        {
            let total = Arc::clone(&total);
            pool.parallel_for_chunked_with(2, n, 1, move |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), n * (n + 1) / 2);
        pool.shutdown();
    });
}
