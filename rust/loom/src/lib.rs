//! Loom harness shell: re-compiles the main crate's `util::sync` facade
//! and `util::par` pool from their canonical sources. With
//! `RUSTFLAGS="--cfg loom"` the facade resolves to `loom::sync`/
//! `loom::thread`, so the models in `tests/models.rs` explore every
//! interleaving of the exact production pool code.

pub mod util {
    #[path = "../../../src/util/sync.rs"]
    pub mod sync;

    #[path = "../../../src/util/par.rs"]
    pub mod par;
}
