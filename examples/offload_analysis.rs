//! Runtime offload: run the congestion analysis through the AOT-compiled
//! XLA artifact (authored in JAX/Pallas at build time, executed via PJRT
//! from rust) and compare results + throughput against the native engine.
//!
//!     make artifacts && cargo run --release --example offload_analysis

use dmodc::analysis::CongestionAnalyzer;
use dmodc::prelude::*;
use dmodc::routing::registry;
use dmodc::runtime::{AnalysisExecutor, ArtifactRegistry};
use dmodc::util::table::{fmt_duration, Table};
use dmodc::util::time::now;

fn main() {
    let reg = ArtifactRegistry::default_location();
    if reg.specs.is_empty() {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("registry: {} artifacts in {}", reg.specs.len(), reg.dir.display());

    let topo = rlft::build(648, 36);
    // Engines resolve by name, like AOT artifacts do in their registry.
    let lft = registry::create_by_name("dmodc")
        .expect("registered engine")
        .route_once(&topo);
    let an = CongestionAnalyzer::new(&topo, &lft);
    let n = topo.nodes.len();

    // Workload: 128 random permutations.
    let mut rng = Rng::new(99);
    let perms: Vec<Vec<u32>> = (0..128).map(|_| rng.permutation(n)).collect();

    // Native baseline.
    let t0 = now();
    let native: Vec<u64> = perms.iter().map(|p| an.perm_max_load(p)).collect();
    let native_dt = t0.elapsed().as_secs_f64();

    let mut tab = Table::new(&["backend", "total", "per perm", "parity"]);
    tab.row(vec![
        "native".into(),
        fmt_duration(native_dt),
        fmt_duration(native_dt / perms.len() as f64),
        "-".into(),
    ]);

    for variant in ["jnp", "pallas"] {
        match AnalysisExecutor::bind(&reg, variant, &topo, an.paths()) {
            Ok(Some(exe)) => {
                // Warm once (compile happens at bind; first execute warms).
                let _ = exe.run(&perms[..1]).unwrap();
                let t0 = now();
                let got = exe.run(&perms).unwrap();
                let dt = t0.elapsed().as_secs_f64();
                let parity = got == native;
                tab.row(vec![
                    format!("artifact/{variant}"),
                    fmt_duration(dt),
                    fmt_duration(dt / perms.len() as f64),
                    if parity { "exact".into() } else { "MISMATCH".into() },
                ]);
                assert!(parity, "{variant} artifact diverged from native engine");
            }
            Ok(None) => println!("no {variant} artifact matches this topology"),
            Err(e) => println!("{variant}: bind failed: {e:#}"),
        }
    }
    print!("{}", tab.render());
    println!("python is build-time only: this binary never imported it.");
}
