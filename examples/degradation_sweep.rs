//! Figure 4-style degradation sweep: congestion risk vs degradation
//! fraction for every registered engine and all three patterns, driven
//! through the `analysis::campaign` engine (reused workspaces + tensors,
//! parallel across samples), emitting the per-sample CSV the python
//! plotting tools consume.
//!
//!     cargo run --release --example degradation_sweep -- \
//!         [--pgft "16,9,12;1,4,6;1,1,1"] [--fractions 0,1,2,5,10] \
//!         [--throws 5] [--csv bench_results/degradation_sweep.csv]

use dmodc::analysis::campaign::{self, CampaignConfig, Schedule};
use dmodc::prelude::*;
use dmodc::util::cli::Args;
use dmodc::util::table::Table;
use dmodc::util::time::now;

fn main() {
    let p = Args::new("degradation_sweep", "Figure 4-style risk-vs-degradation sweep")
        .flag("pgft", "16,9,12;1,4,6;1,1,1", "PGFT parameters (1728 nodes)")
        .flag("fractions", "0,0.5,1,2,5,10", "degradation levels in % of cables")
        .flag("kind", "links", "equipment kind (switches|links)")
        .flag("throws", "5", "random throws per level")
        .flag("seed", "42", "base seed")
        .flag("rp-samples", "100", "random permutations for RP")
        .flag(
            "schedule",
            "independent",
            "throw schedule: independent (paper) | nested (monotone decay)",
        )
        .flag("csv", "bench_results/degradation_sweep.csv", "output CSV path")
        .switch("no-fork", "disable baseline-forked sampling")
        .parse();
    let params = PgftParams::parse(p.get("pgft")).expect("pgft");
    let topo = params.build();
    let equipment = Equipment::parse(p.get("kind")).expect("kind");
    let total = match equipment {
        Equipment::Links => topo.num_cables(),
        Equipment::Switches => topo.switches.len() - topo.leaf_switches().len(),
    };
    // Fractions that round to the same removal count would duplicate
    // grid work and double-weight their summary rows — keep the first.
    let mut fractions: Vec<f64> = Vec::new();
    let mut levels: Vec<usize> = Vec::new();
    for s in p.get("fractions").split(',') {
        let f: f64 = s.trim().parse().expect("fraction");
        let level = ((f / 100.0) * total as f64).round() as usize;
        if levels.contains(&level) {
            println!(
                "note: {f}% rounds to {level} removed {} — already covered, skipped",
                p.get("kind")
            );
        } else {
            fractions.push(f);
            levels.push(level);
        }
    }
    let base_seed = p.get_u64("seed");
    let cfg = CampaignConfig {
        engines: Algo::ALL.to_vec(),
        equipment,
        levels,
        seeds: (0..p.get_u64("throws")).map(|i| base_seed ^ i).collect(),
        patterns: vec![
            Pattern::AllToAll,
            Pattern::RandomPermutation { samples: p.get_usize("rp-samples") },
            Pattern::ShiftPermutation,
        ],
        sp_block: 0,
        workers: 0,
        schedule: Schedule::parse(p.get("schedule")).expect("schedule"),
        fork: !p.get_bool("no-fork"),
    };
    println!(
        "degradation sweep on {} nodes / {} {} total: levels {:?} ({} rows, {} schedule)",
        topo.nodes.len(),
        total,
        p.get("kind"),
        cfg.levels,
        cfg.rows(),
        cfg.schedule.name()
    );
    let t0 = now();
    let (rows, stats) = campaign::run_with_stats(&topo, &cfg);
    let secs = t0.elapsed().as_secs_f64();
    println!("fork stats: {}", stats.render());

    // Risk-vs-degradation curves: median over throws per (engine, level,
    // pattern) — the Figure 4 shape (lower is better).
    let mut tab = Table::new(&["engine", "removed %", "A2A", "RP", "SP", "invalid"]);
    for &algo in &cfg.engines {
        for (li, &level) in cfg.levels.iter().enumerate() {
            let mut cells = vec![
                algo.to_string(),
                format!("{:.1}", fractions[li]),
            ];
            for &pat in &cfg.patterns {
                let mut vals: Vec<u64> = rows
                    .iter()
                    .filter(|r| r.engine == algo && r.level == level && r.pattern == pat)
                    .map(|r| r.value)
                    .collect();
                vals.sort_unstable();
                cells.push(vals.get(vals.len() / 2).copied().unwrap_or(0).to_string());
            }
            let invalid = rows
                .iter()
                .filter(|r| r.engine == algo && r.level == level && !r.valid)
                .count()
                / cfg.patterns.len().max(1);
            cells.push(invalid.to_string());
            tab.row(cells);
        }
    }
    print!("{}", tab.render());

    let path = p.get("csv");
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("create CSV directory");
        }
    }
    campaign::write_csv(&rows, path).expect("write sweep CSV");
    println!(
        "{} samples in {:.2}s ({:.1} samples/s) → {}",
        rows.len(),
        secs,
        rows.len() as f64 / secs.max(1e-9),
        path
    );
}
