//! End-to-end driver: the fabric manager survives a fault storm on a
//! paper-scale PGFT.
//!
//! A producer thread replays a randomized schedule of switch/link faults,
//! recoveries, and whole-islet reboots (the paper's "thousands of
//! simultaneous changes" scenario) into the manager's event loop; the
//! manager reroutes the full fabric from scratch on every event with Dmodc
//! and reports reaction latency and LFT upload deltas. The headline check
//! mirrors the paper's claim: complete rerouting of a many-thousand-node
//! PGFT in well under a second per event.
//!
//!     cargo run --release --example fault_storm -- [--full | --preset huge]

use dmodc::fabric::{events, FabricManager, ManagerConfig};
use dmodc::prelude::*;
use dmodc::util::cli::Args;
use dmodc::util::table::{fmt_duration, Table};
use std::sync::mpsc::channel;

fn main() {
    let p = Args::new("fault_storm", "fabric-manager fault storm")
        .switch("full", "use the full 8640-node Figure-2 topology")
        .flag(
            "preset",
            "",
            "named PGFT preset (fig1|small|paper_8640|huge), overrides --full",
        )
        .flag("events", "30", "number of events")
        .flag("seed", "7", "seed")
        .flag("islet-every", "8", "islet reboot cadence")
        .flag("algo", "dmodc", "routing engine backing the manager")
        .parse();
    let preset = p.get("preset");
    let params = if !preset.is_empty() {
        PgftParams::preset(preset).unwrap_or_else(|e| panic!("bad --preset: {e}"))
    } else if p.get_bool("full") {
        PgftParams::paper_8640()
    } else {
        PgftParams::parse("16,9,12;1,4,6;1,1,1").unwrap() // 1728 nodes
    };
    let topo = params.build();
    println!(
        "fabric: {} nodes / {} switches / {} cables",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_cables()
    );

    let mut rng = Rng::new(p.get_u64("seed"));
    let schedule = events::random_schedule(
        &topo,
        &mut rng,
        p.get_usize("events"),
        50,
        p.get_usize("islet-every"),
    );

    let (etx, erx) = channel();
    let (rtx, rrx) = channel();
    // Any registered engine can back the manager; every one reroutes out
    // of a persistent workspace (see DESIGN.md).
    let algo: Algo = p.get_parsed("algo");
    println!("engine: {algo}");
    let mut mgr = FabricManager::new(
        topo,
        ManagerConfig {
            algo,
            ..Default::default()
        },
    );
    let manager_thread = dmodc::util::sync::thread::spawn_named("fabric-manager", move || {
        mgr.run_stream(erx, rtx);
        mgr
    })
    .expect("spawn manager");
    let producer = dmodc::util::sync::thread::spawn_named("event-producer", move || {
        for e in schedule {
            etx.send(e).unwrap();
        }
    })
    .expect("spawn producer");

    let mut tab = Table::new(&["#", "reroute", "valid", "entriesΔ", "blocksΔ", "alive"]);
    let mut worst = 0f64;
    for r in rrx.iter() {
        worst = worst.max(r.reroute_secs);
        tab.row(vec![
            r.event_idx.to_string(),
            fmt_duration(r.reroute_secs),
            r.valid.to_string(),
            r.upload.entries_changed.to_string(),
            r.upload.blocks_delta.to_string(),
            r.switches_alive.to_string(),
        ]);
    }
    producer.join().unwrap();
    let mgr = manager_thread.join().unwrap();

    print!("{}", tab.render());
    println!("{}", mgr.metrics.render());
    print!("{}", mgr.reroute_hist.render("reroute latency"));
    println!(
        "worst-case reaction: {} — paper's bar: < 1 s for complete rerouting: {}",
        fmt_duration(worst),
        if worst < 1.0 { "MET" } else { "MISSED" }
    );
}
