//! End-to-end stress harness: the long-running fabric service survives a
//! sustained fault storm on a paper-scale PGFT while readers route.
//!
//! A producer paces a randomized schedule of switch/link faults,
//! recoveries, and whole-islet reboots (the paper's "thousands of
//! simultaneous changes" scenario) into a [`FabricService`]; the service
//! coalesces each burst into one reaction and publishes every committed
//! generation as a checksummed epoch. Meanwhile `--readers` threads
//! hammer the published tables with random route lookups and periodic
//! checksum verification — the harness fails if any reader ever observes
//! a torn epoch, or any reaction leaves the fabric invalid.
//!
//! The headline numbers mirror EXPERIMENTS.md §"Fault-storm latency":
//! sustained events/s, coalesce ratio, and the p50/p99 of the true
//! event→publication reaction latency (queue wait + window + reroute),
//! one sample per event. With `BENCH_SERVICE_OUT=path` the same numbers
//! are written as JSON (schema `bench_service/v3`) for the CI soak.
//!
//! `--journal <dir>` makes the run durable: every applied batch is an
//! fsynced journal record and the run ends with an in-process recovery
//! differential (re-open the journal into a second manager, require
//! byte-identical reconvergence). `--kill-every <n>` turns the harness
//! into a crash loop: it `abort()`s after `n` applied events; rerunning
//! the same command line warm-restarts from the journal and produces
//! the remainder of the (deterministic) schedule, until an unkilled run
//! exits 0 (EXPERIMENTS.md §"Crash recovery").
//!
//! `--chaos <seed>` arms the deterministic fault-injection plan
//! ([`ChaosPlan::storm`]) inside the manager: injected reroute panics,
//! corrupted candidates, and stalls (EXPERIMENTS.md §"Chaos soak"). The
//! service must contain/quarantine every one — readers still never see
//! a torn or invalid epoch, and quarantined batches are reported, not
//! silently dropped. Requires a build with the chaos points compiled in
//! (debug, or `--features chaos` in release).
//!
//!     cargo run --release --example fault_storm -- [--full | --preset huge]
//!     cargo run --release --features chaos --example fault_storm -- --chaos 1
//!     cargo run --release --example fault_storm -- --journal /tmp/storm-j --kill-every 16

use dmodc::fabric::{
    events, FabricError, FabricManager, FabricService, JournalConfig, ManagerConfig, QueuePolicy,
    ServiceConfig,
};
use dmodc::util::chaos::{self, ChaosPlan};
use dmodc::prelude::*;
use dmodc::util::cli::Args;
use dmodc::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use dmodc::util::sync::{thread::spawn_named, Arc};
use dmodc::util::table::{fmt_duration, Table};
use dmodc::util::time;
use std::time::Duration;

/// Per-batch rows printed before the table elides the remainder.
const TABLE_ROWS: usize = 32;

fn main() {
    let p = Args::new("fault_storm", "fabric-service fault storm")
        .switch("full", "use the full 8640-node Figure-2 topology")
        .flag(
            "preset",
            "",
            "named PGFT preset (fig1|small|paper_8640|huge), overrides --full",
        )
        .flag("events", "60", "number of events")
        .flag("rate", "200", "producer pace in events/s (0 = blast)")
        .flag("readers", "4", "concurrent reader threads on the published tables")
        .flag("window-ms", "5", "coalescing window (ms, from first event of a burst)")
        .flag("max-batch", "0", "max events per reaction (0 = unbounded)")
        .flag("seed", "7", "seed")
        .flag("islet-every", "8", "islet reboot cadence")
        .flag("algo", "dmodc", "routing engine backing the manager")
        .flag("queue-cap", "0", "event-queue capacity (0 = unbounded)")
        .flag("policy", "block", "full-queue policy (block|coalesce|reject)")
        .flag("watchdog-ms", "0", "reroute watchdog deadline (0 = off)")
        .flag("chaos", "0", "chaos-plan seed (0 = off; needs chaos-enabled build)")
        .flag(
            "journal",
            "",
            "durable-state directory; empty dir = cold start, else warm restart",
        )
        .flag(
            "kill-every",
            "0",
            "with --journal: abort() after this many applied events (0 = run to completion); \
             rerun the same command line until it exits 0",
        )
        .parse();
    let preset = p.get("preset");
    let (name, params) = if !preset.is_empty() {
        let prm = PgftParams::preset(preset).unwrap_or_else(|e| {
            eprintln!("bad --preset: {e}");
            std::process::exit(2);
        });
        (preset.to_string(), prm)
    } else if p.get_bool("full") {
        ("paper_8640".to_string(), PgftParams::paper_8640())
    } else {
        // 1728 nodes
        ("default_1728".to_string(), PgftParams::parse("16,9,12;1,4,6;1,1,1").unwrap())
    };
    let topo = params.build();
    println!(
        "fabric: {} nodes / {} switches / {} cables (preset {name})",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_cables()
    );

    let n_events = p.get_usize("events");
    let rate = p.get_f64("rate");
    let n_readers = p.get_usize("readers");
    let mut rng = Rng::new(p.get_u64("seed"));
    let schedule =
        events::random_schedule(&topo, &mut rng, n_events, 50, p.get_usize("islet-every"));

    let algo: Algo = p.get_parsed("algo");
    let chaos_seed = p.get_u64("chaos");
    if chaos_seed != 0 && !chaos::ENABLED {
        eprintln!(
            "warning: --chaos {chaos_seed} ignored — this build compiled the chaos \
             points out (rebuild with --features chaos)"
        );
    }
    let policy: QueuePolicy = p.get_parsed("policy");
    let journal_dir = p.get("journal").to_string();
    let kill_every = p.get_u64("kill-every") as usize;
    if kill_every > 0 && journal_dir.is_empty() {
        eprintln!("--kill-every needs --journal (nothing survives an abort without one)");
        std::process::exit(2);
    }
    let cfg = ServiceConfig {
        manager: ManagerConfig {
            algo,
            // The storm always runs crash-safe: validate before publish,
            // quarantine with rollback on failure.
            gate: true,
            watchdog_ms: p.get_u64("watchdog-ms"),
            chaos: (chaos_seed != 0).then(|| ChaosPlan::storm(chaos_seed)),
            ..Default::default()
        },
        window_ms: p.get_u64("window-ms"),
        max_batch: p.get_usize("max-batch"),
        queue_cap: p.get_usize("queue-cap"),
        policy,
        journal: (!journal_dir.is_empty()).then(|| JournalConfig::new(&journal_dir)),
    };
    println!(
        "engine: {algo}  window: {}ms  max_batch: {}  rate: {rate}/s  readers: {n_readers}  \
         queue_cap: {}  policy: {}  watchdog: {}ms  chaos: {chaos_seed}  journal: {}",
        cfg.window_ms,
        cfg.max_batch,
        cfg.queue_cap,
        policy.name(),
        cfg.manager.watchdog_ms,
        if journal_dir.is_empty() { "off" } else { &journal_dir }
    );
    let nodes = topo.nodes.len();
    let switches = topo.switches.len();
    // Keep a reference copy for the post-run recovery differential.
    let reference = (!journal_dir.is_empty()).then(|| topo.clone());
    let svc = if journal_dir.is_empty() {
        let mgr = FabricManager::new(topo, cfg.manager.clone());
        FabricService::spawn_with(mgr, cfg.clone()).unwrap_or_else(|e| {
            eprintln!("could not start the fabric service: {e}");
            std::process::exit(1);
        })
    } else {
        // With a journal, always go through resume: an empty directory
        // is a cold start, recoverable state is a warm restart — the
        // kill/resume loop reruns one command line until it exits 0.
        FabricService::resume(topo, cfg.clone()).unwrap_or_else(|e| {
            eprintln!("could not resume the fabric service: {e}");
            std::process::exit(1);
        })
    };
    let start = (svc.events_recovered() as usize).min(schedule.len());
    if start > 0 {
        println!(
            "warm restart: {start}/{} events already applied, producing the rest",
            schedule.len()
        );
    }

    // Reader fleet: random route lookups against whatever epoch is
    // current, a full checksum verification every 256 reads, per-thread
    // epoch monotonicity. Torn or regressed epochs fail the harness.
    let stop = Arc::new(AtomicBool::new(false));
    let torn = Arc::new(AtomicU64::new(0));
    let mut reader_threads = Vec::new();
    for r in 0..n_readers {
        let reader = svc.reader();
        let stop = Arc::clone(&stop);
        let torn = Arc::clone(&torn);
        let seed = p.get_u64("seed") ^ (0x9E37 + r as u64);
        reader_threads.push(
            spawn_named(&format!("storm-reader-{r}"), move || {
                let mut rng = Rng::new(seed);
                let mut reads = 0u64;
                let mut last_epoch = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let ep = reader.tables();
                    if ep.epoch() < last_epoch {
                        torn.fetch_add(1, Ordering::Relaxed);
                    }
                    last_epoch = ep.epoch();
                    let sw = rng.gen_range(ep.num_switches());
                    let dst = rng.gen_range(ep.num_nodes()) as u32;
                    std::hint::black_box(ep.port(sw, dst));
                    reads += 1;
                    if reads % 256 == 0 && ep.verify().is_err() {
                        torn.fetch_add(1, Ordering::Relaxed);
                    }
                }
                reads
            })
            .expect("spawn reader"),
        );
    }

    // Paced producer on this thread; reports drained inline (the report
    // channel is unbounded, recv() below never deadlocks the loop).
    let sender = svc.sender();
    let gap = if rate > 0.0 {
        Duration::from_secs_f64(1.0 / rate)
    } else {
        Duration::ZERO
    };
    let t0 = time::now();
    let mut next_send = t0;
    let mut shed = 0usize;
    let mut produced = 0usize;
    let mut killing = false;
    for e in &schedule[start..] {
        if kill_every > 0 && produced >= kill_every && start + produced < schedule.len() {
            killing = true;
            break;
        }
        if !gap.is_zero() {
            let now = time::now();
            let wait = next_send.saturating_duration_since(now);
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            next_send += gap;
        }
        // A RejectNewest queue sheds under pressure: that's the policy
        // doing its job — the producer learns exactly which event was
        // dropped and accounts for it.
        if let Err(err) = sender.send(e.clone()) {
            match err {
                FabricError::QueueFull { .. } => shed += 1,
                other => {
                    eprintln!("fabric service stopped while the storm was still feeding: {other}");
                    std::process::exit(1);
                }
            }
        }
        produced += 1;
    }
    drop(sender);

    // Every non-shed event ends up in exactly one report (applied or
    // quarantined — never silently dropped); collect until the counts
    // balance, then shut the loop down.
    let mut tab = Table::new(&[
        "batch", "events", "tier", "reaction", "valid", "entriesΔ", "alive", "outcome",
    ]);
    let mut seen = 0usize;
    let mut invalid = 0usize;
    let mut quarantined = 0usize;
    let mut elided = 0usize;
    while seen + shed < produced {
        let br = match svc.reports().recv() {
            Ok(br) => br,
            Err(_) => {
                eprintln!(
                    "fabric service stopped before the storm drained \
                     ({seen}/{produced} events reported, {shed} shed)"
                );
                std::process::exit(1);
            }
        };
        seen += br.events;
        // Quarantined batches carry a synthesized post-rollback report;
        // only an *applied* invalid reaction is a harness failure.
        if br.quarantined.is_some() {
            quarantined += 1;
        } else if !br.report.valid {
            invalid += 1;
        }
        if br.batch_idx < TABLE_ROWS {
            tab.row(vec![
                br.batch_idx.to_string(),
                br.events.to_string(),
                format!("{:?}", br.report.tier),
                fmt_duration(br.reaction_s),
                br.report.valid.to_string(),
                br.report.upload.entries_changed.to_string(),
                br.report.switches_alive.to_string(),
                br.quarantined
                    .as_ref()
                    .map_or_else(|| "applied".into(), |q| format!("quarantined:{}", q.tag())),
            ]);
        } else {
            elided += 1;
        }
    }
    if killing {
        // Kill point: every journaled batch is fsynced (its report came
        // back), no clean shutdown follows — the closest in-process
        // stand-in for `kill -9`. The rerun resumes from the journal.
        eprintln!(
            "kill point: aborting after {} applied events ({} total on the schedule)",
            start + produced,
            schedule.len()
        );
        std::process::abort();
    }
    let storm_s = time::now().saturating_duration_since(t0).as_secs_f64();
    let (mgr, stats) = svc.shutdown();
    stop.store(true, Ordering::Relaxed);
    let mut reader_reads = 0u64;
    for h in reader_threads {
        reader_reads += h.join().expect("reader panicked");
    }
    let torn = torn.load(Ordering::Relaxed);

    // Recovery differential: re-open the journal into a second manager
    // and require byte-identical reconvergence with the live run. The
    // epoch/LFT/dead-set comparison is quarantine-invariant (quarantined
    // batches neither publish nor journal); events_seen only matches
    // when nothing was quarantined.
    let mut recovery_diverged = false;
    if let Some(reference) = reference {
        match FabricManager::resume_from_dir(
            reference,
            cfg.manager.clone(),
            JournalConfig::new(&journal_dir),
        ) {
            Ok((mgr2, _journal, info)) => {
                let identical = mgr2.current().1.raw() == mgr.current().1.raw()
                    && mgr2.dead_equipment() == mgr.dead_equipment()
                    && mgr2.reader().tables().epoch() == mgr.reader().tables().epoch()
                    && (quarantined > 0 || mgr2.events_seen() == mgr.events_seen());
                recovery_diverged = !identical;
                println!(
                    "recovery differential: {} (replayed {} events over {} snapshot state, \
                     {} truncated tails, {:.2}ms)",
                    if identical { "identical" } else { "DIVERGED" },
                    info.replayed_events,
                    if info.cold_start { "no" } else { "a" },
                    info.tail_truncations,
                    info.resume_ms
                );
            }
            Err(e) => {
                recovery_diverged = true;
                eprintln!("recovery differential: resume failed: {e}");
            }
        }
    }

    print!("{}", tab.render());
    if elided > 0 {
        println!("… ({elided} more batches)");
    }
    println!("{}", mgr.metrics.render());
    print!("{}", mgr.reroute_hist.render("reroute latency"));
    print!("{}", stats.reaction.render("reaction latency"));
    let events_per_s = if storm_s > 0.0 {
        stats.events as f64 / storm_s
    } else {
        0.0
    };
    let reads_per_s = if storm_s > 0.0 {
        reader_reads as f64 / storm_s
    } else {
        0.0
    };
    println!(
        "storm: {} events in {} → {:.1} events/s, {} reactions (coalesce ratio {:.2}, peak batch {})",
        stats.events,
        fmt_duration(storm_s),
        events_per_s,
        stats.batches,
        stats.coalesce_ratio(),
        stats.max_batch
    );
    println!(
        "readers: {n_readers} threads, {reader_reads} lookups ({reads_per_s:.0}/s), torn epochs: {torn}"
    );
    println!(
        "ladder: quarantined={quarantined} shed={shed} folded={} high_water={} \
         panics_contained={} watchdog={} rejected={} rollbacks={}",
        stats.events_folded,
        stats.queue_high_water,
        mgr.metrics.panics_contained,
        mgr.metrics.watchdog_escalations,
        mgr.metrics.epochs_rejected,
        mgr.metrics.rollbacks
    );
    if stats.recovery.count() > 0 {
        print!("{}", stats.recovery.render("recovery latency"));
    }
    let p50 = stats.reaction.quantile(0.5);
    let p99 = stats.reaction.quantile(0.99);
    let bar = if stats.reaction.max() < 1000.0 {
        "MET"
    } else {
        "MISSED"
    };
    println!(
        "reaction (event→publication): p50≤{:.2}ms p99≤{:.2}ms max={:.2}ms — paper's bar: < 1 s: {}",
        p50,
        p99,
        stats.reaction.max(),
        bar
    );

    if let Ok(out_path) = std::env::var("BENCH_SERVICE_OUT") {
        let threads = std::env::var("DMODC_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        let shed_rate = if schedule.is_empty() {
            0.0
        } else {
            shed as f64 / schedule.len() as f64
        };
        let json = format!(
            concat!(
                "{{\n",
                "  \"schema\": \"bench_service/v3\",\n",
                "  \"status\": \"ok\",\n",
                "  \"preset\": \"{name}\",\n",
                "  \"topology\": \"PGFT({spec})\",\n",
                "  \"nodes\": {nodes},\n",
                "  \"switches\": {switches},\n",
                "  \"threads\": {threads},\n",
                "  \"window_ms\": {window},\n",
                "  \"max_batch\": {max_batch},\n",
                "  \"rate_target\": {rate:.1},\n",
                "  \"queue_cap\": {queue_cap},\n",
                "  \"policy\": \"{policy}\",\n",
                "  \"chaos_seed\": {chaos_seed},\n",
                "  \"events\": {events},\n",
                "  \"batches\": {batches},\n",
                "  \"events_per_s\": {eps:.2},\n",
                "  \"coalesce_ratio\": {ratio:.4},\n",
                "  \"peak_batch\": {peak},\n",
                "  \"reaction_p50_ms\": {p50:.4},\n",
                "  \"reaction_p99_ms\": {p99:.4},\n",
                "  \"reaction_max_ms\": {pmax:.4},\n",
                "  \"reaction_mean_ms\": {pmean:.4},\n",
                "  \"recovery_p50_ms\": {r50:.4},\n",
                "  \"recovery_p99_ms\": {r99:.4},\n",
                "  \"recovery_events\": {rn},\n",
                "  \"quarantined_batches\": {quarantined},\n",
                "  \"epochs_rejected\": {rejected},\n",
                "  \"rollbacks\": {rollbacks},\n",
                "  \"panics_contained\": {panics},\n",
                "  \"watchdog_escalations\": {watchdog},\n",
                "  \"events_shed\": {shed},\n",
                "  \"shed_rate\": {shed_rate:.4},\n",
                "  \"events_folded\": {folded},\n",
                "  \"queue_high_water\": {high_water},\n",
                "  \"delta_reroutes\": {dr},\n",
                "  \"delta_fallbacks\": {df},\n",
                "  \"delta_ineligible\": {di},\n",
                "  \"readers\": {readers},\n",
                "  \"reader_reads\": {reads},\n",
                "  \"reader_reads_per_s\": {rps:.0},\n",
                "  \"torn_reads\": {torn},\n",
                "  \"invalid_reactions\": {invalid},\n",
                "  \"journal_appends\": {j_appends},\n",
                "  \"journal_bytes\": {j_bytes},\n",
                "  \"snapshots_written\": {snaps},\n",
                "  \"snapshot_bytes\": {snap_bytes},\n",
                "  \"compactions\": {compactions},\n",
                "  \"resume_ms\": {resume_ms:.4},\n",
                "  \"replayed_events\": {replayed},\n",
                "  \"tail_truncations\": {truncations}\n",
                "}}\n"
            ),
            name = name,
            spec = params,
            nodes = nodes,
            switches = switches,
            threads = threads,
            window = cfg.window_ms,
            max_batch = cfg.max_batch,
            rate = rate,
            queue_cap = cfg.queue_cap,
            policy = policy.name(),
            chaos_seed = chaos_seed,
            events = stats.events,
            batches = stats.batches,
            eps = events_per_s,
            ratio = stats.coalesce_ratio(),
            peak = stats.max_batch,
            p50 = p50,
            p99 = p99,
            pmax = stats.reaction.max(),
            pmean = stats.reaction.mean(),
            r50 = stats.recovery.quantile(0.5),
            r99 = stats.recovery.quantile(0.99),
            rn = stats.recovery.count(),
            quarantined = quarantined,
            rejected = mgr.metrics.epochs_rejected,
            rollbacks = mgr.metrics.rollbacks,
            panics = mgr.metrics.panics_contained,
            watchdog = mgr.metrics.watchdog_escalations,
            shed = shed,
            shed_rate = shed_rate,
            folded = stats.events_folded,
            high_water = stats.queue_high_water,
            dr = mgr.metrics.delta_reroutes,
            df = mgr.metrics.delta_fallbacks,
            di = mgr.metrics.delta_ineligible,
            readers = n_readers,
            reads = reader_reads,
            rps = reads_per_s,
            torn = torn,
            invalid = invalid,
            j_appends = stats.journal_appends,
            j_bytes = stats.journal_bytes,
            snaps = stats.snapshots_written,
            snap_bytes = stats.snapshot_bytes,
            compactions = stats.compactions,
            resume_ms = stats.resume_ms,
            replayed = stats.resume_replayed,
            truncations = stats.tail_truncations,
        );
        if let Err(e) = std::fs::write(&out_path, &json) {
            eprintln!("could not write bench JSON {out_path}: {e}");
            std::process::exit(1);
        }
        println!("→ {out_path}");
    }

    if torn > 0 || invalid > 0 || recovery_diverged {
        eprintln!(
            "FAIL: torn epochs {torn}, invalid reactions {invalid}, recovery diverged: \
             {recovery_diverged}"
        );
        std::process::exit(1);
    }
}
