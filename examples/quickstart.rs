//! Quickstart: build a PGFT, route it with Dmodc, validate, analyze.
//!
//!     cargo run --release --example quickstart

use dmodc::analysis::CongestionAnalyzer;
use dmodc::prelude::*;
use dmodc::routing::{registry, validity};

fn main() {
    // The paper's Figure 1 example: PGFT(3; 2,2,3; 1,2,2; 1,2,1).
    let topo = PgftParams::fig1().build();
    println!(
        "PGFT(3; 2,2,3; 1,2,2; 1,2,1): {} nodes, {} switches, {} cables",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_cables()
    );

    // Route with the paper's algorithm and check the validity condition.
    let lft = route(Algo::Dmodc, &topo).expect("intact PGFT always routes");
    let stats = validity::stats(&topo, &lft);
    println!(
        "dmodc: {} routes, mean {:.2} hops, up*/down* shaped: {}",
        stats.routes,
        stats.mean_hops(),
        stats.downup_turns == 0
    );

    // Static congestion-risk analysis (paper §4).
    let analyzer = CongestionAnalyzer::new(&topo, &lft);
    println!("A2A congestion risk: {}", analyzer.all_to_all());
    println!("RP  congestion risk: {}", analyzer.random_perm_median(200, 42));
    println!("SP  congestion risk: {}", analyzer.shift_max());

    // Break something and watch Dmodc reroute around it — through the
    // stateful engine API this time: one engine reused across reroutes
    // keeps every pipeline buffer warm (the fabric manager's hot path),
    // and its validate() reuses the costs the reroute just computed.
    let mut engine = registry::create(Algo::Dmodc);
    let mut rng = Rng::new(7);
    let degraded_topo = degrade::remove_random_links(&topo, &mut rng, 3);
    let mut lft2 = Lft::default();
    engine.route_into(&degraded_topo, &mut lft2);
    engine.validate(&degraded_topo, &lft2).expect("still connected");
    let analyzer2 = CongestionAnalyzer::new(&degraded_topo, &lft2);
    println!(
        "after losing 3 cables: A2A {} SP {}",
        analyzer2.all_to_all(),
        analyzer2.shift_max()
    );
}
