//! Mini Figure 2: congestion risk of every engine across degradation
//! levels on a medium PGFT (same harness shape as the full bench, sized to
//! finish in seconds).
//!
//!     cargo run --release --example degradation_study -- [--nodes 648]

use dmodc::analysis::CongestionAnalyzer;
use dmodc::prelude::*;
use dmodc::routing::registry;
use dmodc::util::cli::Args;
use dmodc::util::table::Table;

fn main() {
    let p = Args::new("degradation_study", "mini Figure 2")
        .flag("pgft", "16,9,12;1,4,6;1,1,1", "PGFT parameters (1728 nodes, blocking 4)")
        .flag("seed", "42", "seed")
        .flag("rp-samples", "50", "RP samples per point")
        .switch("scrambled-uuids", "use fabrication-scrambled UUIDs instead of install-order")
        .parse();
    // Install-order UUIDs by default: the paper aligns the shift ordering
    // with Ftree's internal (UUID) order, which on a production fabric
    // follows physical install order — this is what makes the SP
    // comparison "fair" (§4).
    let mut params = PgftParams::parse(p.get("pgft")).expect("pgft");
    if !p.get_bool("scrambled-uuids") {
        params = params.with_uuid_mode(dmodc::topology::pgft::UuidMode::Sequential);
    }
    let topo = params.build();
    println!(
        "topology: {} nodes / {} switches / {} cables",
        topo.nodes.len(),
        topo.switches.len(),
        topo.num_cables()
    );

    let mut tab = Table::new(&["removed sw", "algo", "valid", "A2A", "RP", "SP"]);
    let mut rng = Rng::new(p.get_u64("seed"));
    // One persistent engine per algorithm: workspaces stay warm across
    // all degradation levels (the RoutingEngine redesign's reuse path).
    let mut engines: Vec<Box<dyn RoutingEngine>> =
        Algo::PAPER.iter().map(|&a| registry::create(a)).collect();
    let mut lft = Lft::default();
    for amount in [0usize, 2, 8, 24, 48, 96] {
        let degraded = degrade::remove_random_switches(&topo, &mut rng, amount);
        for engine in engines.iter_mut() {
            engine.route_into(&degraded, &mut lft);
            let valid = engine.validate(&degraded, &lft).is_ok();
            let an = CongestionAnalyzer::new(&degraded, &lft);
            tab.row(vec![
                amount.to_string(),
                engine.name().to_string(),
                valid.to_string(),
                an.all_to_all().to_string(),
                an.random_perm_median(p.get_usize("rp-samples"), 1).to_string(),
                an.shift_max().to_string(),
            ]);
        }
    }
    print!("{}", tab.render());
    println!("(lower is better; the full harness is `cargo bench --bench fig2_congestion`)");
}
